"""Batched serving engines.

``ServingEngine`` drives the transformer's prefill/decode entry points
for a batch of requests with continuous greedy/temperature decoding; the
same ``decode_step``/``prefill`` functions are what the dry-run lowers
for the ``decode_*``/``prefill_*`` shape cells.

``GNNServingEngine`` serves node-classification queries over a fixed
graph (the paper's driving app): the SpMM aggregation path is chosen
once per graph by the sparsity-adaptive dispatch layer and baked into
the jitted forward, and the engine reports which path serves traffic.

Long-context (500k) decode shards the KV cache over mesh axes via the
logical-axis rules ("kv_seq"); see launch/dryrun.py shape policies.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import decode_step, init_cache, prefill


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 2048
    temperature: float = 0.0  # 0 => greedy
    seed: int = 0


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, scfg: ServeConfig):
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self._prefill = jax.jit(
            lambda p, toks, **kw: prefill(p, cfg, toks, scfg.max_len, **kw))
        self._decode = jax.jit(
            lambda p, tok, cache: decode_step(p, cfg, tok, cache))
        self._key = jax.random.PRNGKey(scfg.seed)

    def _sample(self, logits):
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._key, sub = jax.random.split(self._key)
        return jax.random.categorical(
            sub, logits / self.scfg.temperature, axis=-1).astype(jnp.int32)

    def generate(self, prompts: np.ndarray, n_new: int, *,
                 vision_embeds=None, enc_embeds=None) -> np.ndarray:
        """prompts: [B, S_prompt] int32 -> [B, n_new] generated tokens."""
        kw = {}
        if vision_embeds is not None:
            kw["vision_embeds"] = vision_embeds
        if enc_embeds is not None:
            kw["enc_embeds"] = enc_embeds
        logits, cache = self._prefill(self.params, jnp.asarray(prompts), **kw)
        toks = []
        tok = self._sample(logits)[:, None]
        toks.append(tok)
        for _ in range(n_new - 1):
            logits, cache = self._decode(self.params, tok, cache)
            tok = self._sample(logits)[:, None]
            toks.append(tok)
        return np.asarray(jnp.concatenate(toks, axis=1))


@dataclasses.dataclass
class GNNServeConfig:
    policy: str = "auto"   # dispatch policy for the aggregation SpMM
    jit: bool = True


class GNNServingEngine:
    """Serves GCN node-classification over a fixed graph.

    The dispatch plan is made once at construction (host side, from the
    graph's static sparsity stats) and the jitted forward executes the
    chosen path for every query batch — the serving analog of the
    paper's per-workload kernel selection.
    """

    def __init__(self, params, graph, scfg: Optional[GNNServeConfig] = None):
        from repro.dispatch.dispatcher import plan_spmm
        from repro.models.gnn import GRAPH_PATHS, gcn_forward

        self.params = params
        self.graph = graph
        self.scfg = scfg or GNNServeConfig()
        if graph.adj is None or graph.adj.stats is None:
            raise ValueError(
                "GNNServingEngine: Graph adjacency has no sparsity stats; "
                "construct it with build_graph()")
        # feature width varies per layer; plan with the first layer's
        # output width (the widths only scale every path's cost equally)
        d = int(np.asarray(params["w"][0]).shape[1])
        self.plan = plan_spmm(graph.adj.stats, d, policy=self.scfg.policy,
                              candidates=GRAPH_PATHS)

        def fwd(p, g, x):
            return gcn_forward(p, g, x, policy=self.plan.path)

        self._fwd = jax.jit(fwd) if self.scfg.jit else fwd
        self.n_requests = 0

    def infer(self, x) -> np.ndarray:
        """x: [n_nodes, in_features] -> logits [n_nodes, n_classes]."""
        self.n_requests += 1
        return np.asarray(self._fwd(self.params, self.graph, jnp.asarray(x)))

    def classify(self, x) -> np.ndarray:
        return self.infer(x).argmax(axis=-1)

    def dispatch_report(self) -> Dict:
        """Which path serves this graph's traffic, and why."""
        from repro.sparse import plan_cache_stats

        stats = self.graph.adj.stats
        return {
            "path": self.plan.path,
            "policy": self.plan.policy,
            "reason": self.plan.reason,
            "density": stats.density,
            "occupancy": stats.occupancy,
            "padded_stream_blowup": stats.padded_stream_blowup,
            "n_requests": self.n_requests,
            "plan_cache": plan_cache_stats(),
        }


def make_prefill_step(cfg: ModelConfig, max_len: int):
    """Lowerable prefill entry (the prefill_* dry-run cells).

    Takes the batch as a dict so modality side-inputs can never be
    positionally confused (a vision_embeds/enc_embeds swap silently drops
    the whisper encoder — caught by the multi-pod dry-run's out_shardings
    structure check).
    """

    def prefill_step(params, batch):
        kw = {}
        if cfg.vision_tokens and "vision_embeds" in batch:
            kw["vision_embeds"] = batch["vision_embeds"]
        if cfg.encoder_layers and "enc_embeds" in batch:
            kw["enc_embeds"] = batch["enc_embeds"]
        logits, cache = prefill(params, cfg, batch["tokens"], max_len, **kw)
        return logits, cache

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """Lowerable single-token decode entry (the decode_* dry-run cells)."""

    def serve_step(params, token, cache):
        return decode_step(params, cfg, token, cache)

    return serve_step
