"""Batched serving engine: prefill + decode with a pytree KV cache.

``ServingEngine`` drives the model's prefill/decode entry points for a
batch of requests with continuous greedy/temperature decoding; the same
``decode_step``/``prefill`` functions are what the dry-run lowers for the
``decode_*``/``prefill_*`` shape cells.

Long-context (500k) decode shards the KV cache over mesh axes via the
logical-axis rules ("kv_seq"); see launch/dryrun.py shape policies.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import decode_step, init_cache, prefill


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 2048
    temperature: float = 0.0  # 0 => greedy
    seed: int = 0


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, scfg: ServeConfig):
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self._prefill = jax.jit(
            lambda p, toks, **kw: prefill(p, cfg, toks, scfg.max_len, **kw))
        self._decode = jax.jit(
            lambda p, tok, cache: decode_step(p, cfg, tok, cache))
        self._key = jax.random.PRNGKey(scfg.seed)

    def _sample(self, logits):
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._key, sub = jax.random.split(self._key)
        return jax.random.categorical(
            sub, logits / self.scfg.temperature, axis=-1).astype(jnp.int32)

    def generate(self, prompts: np.ndarray, n_new: int, *,
                 vision_embeds=None, enc_embeds=None) -> np.ndarray:
        """prompts: [B, S_prompt] int32 -> [B, n_new] generated tokens."""
        kw = {}
        if vision_embeds is not None:
            kw["vision_embeds"] = vision_embeds
        if enc_embeds is not None:
            kw["enc_embeds"] = enc_embeds
        logits, cache = self._prefill(self.params, jnp.asarray(prompts), **kw)
        toks = []
        tok = self._sample(logits)[:, None]
        toks.append(tok)
        for _ in range(n_new - 1):
            logits, cache = self._decode(self.params, tok, cache)
            tok = self._sample(logits)[:, None]
            toks.append(tok)
        return np.asarray(jnp.concatenate(toks, axis=1))


def make_prefill_step(cfg: ModelConfig, max_len: int):
    """Lowerable prefill entry (the prefill_* dry-run cells).

    Takes the batch as a dict so modality side-inputs can never be
    positionally confused (a vision_embeds/enc_embeds swap silently drops
    the whisper encoder — caught by the multi-pod dry-run's out_shardings
    structure check).
    """

    def prefill_step(params, batch):
        kw = {}
        if cfg.vision_tokens and "vision_embeds" in batch:
            kw["vision_embeds"] = batch["vision_embeds"]
        if cfg.encoder_layers and "enc_embeds" in batch:
            kw["enc_embeds"] = batch["enc_embeds"]
        logits, cache = prefill(params, cfg, batch["tokens"], max_len, **kw)
        return logits, cache

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """Lowerable single-token decode entry (the decode_* dry-run cells)."""

    def serve_step(params, token, cache):
        return decode_step(params, cfg, token, cache)

    return serve_step
