"""Batched serving engines.

``ServingEngine`` drives the transformer's prefill/decode entry points
for a batch of requests with continuous greedy/temperature decoding; the
same ``decode_step``/``prefill`` functions are what the dry-run lowers
for the ``decode_*``/``prefill_*`` shape cells.

``GNNServingEngine`` serves node-classification queries over a fixed
graph (the paper's driving app): the SpMM aggregation path is chosen
once per graph by the sparsity-adaptive dispatch layer and baked into
the jitted forward, and the engine reports which path serves traffic.

``BatchServingEngine`` serves a *stream* of variably-shaped graphs: a
bounded request queue feeds a micro-batching worker (flush on max-batch
or deadline) that groups requests by shape bucket and executes each
group as one block-diagonal batch through the bucketed compilation
cache (``repro.batch``) — compiles stay O(#buckets) while the report
tracks req/s, p50/p99 latency, retraces, and padding waste.

Long-context (500k) decode shards the KV cache over mesh axes via the
logical-axis rules ("kv_seq"); see launch/dryrun.py shape policies.
"""
from __future__ import annotations

import dataclasses
import queue as queue_mod
import threading
import time
from concurrent.futures import Future
from concurrent.futures import TimeoutError as _FutTimeout
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ModelConfig
from repro.models.transformer import decode_step, init_cache, prefill
from repro.resilience import chaos
from repro.resilience.errors import (FATAL, POISON, TRANSIENT,
                                     DeadlineExceededError,
                                     EngineClosedError, NaNOutputError,
                                     TransientExecutorError, classify)
from repro.resilience.retry import RetryBudget, RetryPolicy
from repro.resilience.supervisor import WorkerSupervisor


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 2048
    temperature: float = 0.0  # 0 => greedy
    seed: int = 0


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, scfg: ServeConfig):
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self._prefill = jax.jit(
            lambda p, toks, **kw: prefill(p, cfg, toks, scfg.max_len, **kw))
        self._decode = jax.jit(
            lambda p, tok, cache: decode_step(p, cfg, tok, cache))
        self._key = jax.random.PRNGKey(scfg.seed)

    def _sample(self, logits):
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._key, sub = jax.random.split(self._key)
        return jax.random.categorical(
            sub, logits / self.scfg.temperature, axis=-1).astype(jnp.int32)

    def generate(self, prompts: np.ndarray, n_new: int, *,
                 vision_embeds=None, enc_embeds=None) -> np.ndarray:
        """prompts: [B, S_prompt] int32 -> [B, n_new] generated tokens."""
        kw = {}
        if vision_embeds is not None:
            kw["vision_embeds"] = vision_embeds
        if enc_embeds is not None:
            kw["enc_embeds"] = enc_embeds
        logits, cache = self._prefill(self.params, jnp.asarray(prompts), **kw)
        toks = []
        tok = self._sample(logits)[:, None]
        toks.append(tok)
        for _ in range(n_new - 1):
            logits, cache = self._decode(self.params, tok, cache)
            tok = self._sample(logits)[:, None]
            toks.append(tok)
        return np.asarray(jnp.concatenate(toks, axis=1))


@dataclasses.dataclass
class GNNServeConfig:
    policy: str = "auto"   # dispatch policy for the aggregation SpMM
    jit: bool = True
    d: Optional[int] = None  # planning feature width (inferred if None)
    model: str = "gcn"     # "gcn" | "gat"
    fuse: bool = True      # fused epilogue (gcn) / one-pass attn (gat)


def _infer_planning_width(params) -> int:
    """Feature width the SpMM plan prices, from any GNN param layout.

    Prefers the first layer's output projection when the params follow
    the ``{"w": [...]}`` convention; otherwise falls back to the first
    2-D leaf in pytree order (the widths only scale every path's cost
    equally, so any layer's width ranks the paths identically).
    """
    ws = params.get("w") if isinstance(params, dict) else None
    if isinstance(ws, (list, tuple)):
        ws = ws[0] if ws else None
    if ws is not None and getattr(ws, "ndim", 0) == 2:
        return int(np.shape(ws)[1])
    for leaf in jax.tree_util.tree_leaves(params):
        if getattr(leaf, "ndim", 0) == 2:
            return int(np.shape(leaf)[1])
    raise ValueError(
        "could not infer a planning feature width from the params "
        "(no 2-D weight leaf); pass GNNServeConfig(d=...) explicitly")


class GNNServingEngine:
    """Serves GCN node-classification over a fixed graph.

    The dispatch plan is made once at construction (host side, from the
    graph's static sparsity stats) and the jitted forward executes the
    chosen path for every query batch — the serving analog of the
    paper's per-workload kernel selection.
    """

    def __init__(self, params, graph, scfg: Optional[GNNServeConfig] = None):
        from repro.dispatch.dispatcher import plan_fused_attention, plan_spmm
        from repro.models.gnn import (GRAPH_PATHS, gat_forward, gcn_forward,
                                      graph_candidates)

        self.params = params
        self.graph = graph
        self.scfg = scfg or GNNServeConfig()
        if graph.adj is None or graph.adj.stats is None:
            raise ValueError(
                "GNNServingEngine: Graph adjacency has no sparsity stats; "
                "construct it with build_graph()")
        if self.scfg.model not in ("gcn", "gat"):
            raise ValueError(
                f"GNNServeConfig.model must be 'gcn' or 'gat', got "
                f"{self.scfg.model!r}")
        d = self.scfg.d if self.scfg.d is not None \
            else _infer_planning_width(params)
        # candidates: the paths this graph's carried forms can execute
        # (a hyper-sparse adjacency also packs SELL-C-σ — see build_graph)
        cand = graph_candidates(graph.adj)
        fuse = self.scfg.fuse
        if self.scfg.model == "gat" and fuse:
            # one-pass attention: priced as a single stream of the
            # topology at the combined (score + value) width
            self.plan = plan_fused_attention(
                graph.adj.stats, 2, d, policy=self.scfg.policy,
                candidates=cand or GRAPH_PATHS)
        else:
            self.plan = plan_spmm(graph.adj.stats, d,
                                  policy=self.scfg.policy,
                                  candidates=cand or GRAPH_PATHS)

        if self.scfg.model == "gat":
            # unfused GAT samples on the element pattern, so the baked
            # layout plan only applies to the fused one-pass pipeline
            gat_policy = self.plan.path if fuse else self.scfg.policy

            def fwd(p, g, x):
                return gat_forward(p, g, x, policy=gat_policy, fuse=fuse)
        else:
            def fwd(p, g, x):
                return gcn_forward(p, g, x, policy=self.plan.path,
                                   fuse=fuse)

        self._fwd = jax.jit(fwd) if self.scfg.jit else fwd
        self.n_requests = 0

    def infer(self, x) -> np.ndarray:
        """x: [n_nodes, in_features] -> logits [n_nodes, n_classes]."""
        self.n_requests += 1
        return np.asarray(self._fwd(self.params, self.graph, jnp.asarray(x)))

    def classify(self, x) -> np.ndarray:
        return self.infer(x).argmax(axis=-1)

    def dispatch_report(self) -> Dict:
        """Which path serves this graph's traffic, and why."""
        from repro.sparse import plan_cache_stats

        stats = self.graph.adj.stats
        return {
            "model": self.scfg.model,
            "fused": self.scfg.fuse,
            "plan_op": self.plan.op,
            "path": self.plan.path,
            "policy": self.plan.policy,
            "reason": self.plan.reason,
            "density": stats.density,
            "occupancy": stats.occupancy,
            "padded_stream_blowup": stats.padded_stream_blowup,
            "n_requests": self.n_requests,
            # the served graph's own plan memo (per-matrix counters):
            # engines on distinct graphs no longer alias each other's
            # hit rates; engines sharing one Graph share its memo
            "plan_cache": self.graph.adj.plan_cache.stats(),
            "plan_cache_global": plan_cache_stats(),
        }


# ---------------------------------------------------------------------------
# Batched multi-graph serving (micro-batching over the bucketed executor)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BatchServeConfig:
    """Micro-batching window and bucketed-executor knobs."""

    max_batch: int = 32        # flush when this many requests are queued
    max_delay_ms: float = 5.0  # ... or when the oldest waits this long
    queue_depth: int = 1024    # bounded admission queue
    policy: str = "auto"       # dispatch policy inside the executor
    form: str = "auto"         # bucket form: auto | csr | ell
    max_executors: int = 64    # LRU cap on cached jitted executors
    growth: float = 2.0        # bucket grid growth factor
    fuse: bool = True          # fused epilogue inside the GCN executor
    # opt into the traffic-fitted bucket grid (an AdaptiveBucketLadder
    # replaces the fixed geometric grid once it has observed enough
    # traffic; see repro.serve.runtime).  ``ladder`` overrides its
    # LadderConfig.
    adaptive: bool = False
    ladder: Any = None
    # -- resilience (see DESIGN.md "Resilience") ----------------------------
    retry: RetryPolicy = RetryPolicy()  # per-request backoff + allowance
    retry_budget: int = 64              # engine-wide retry tokens
    retry_refill_per_s: float = 8.0
    guard_nonfinite: bool = True        # quarantine NaN/Inf outputs
    default_timeout_s: Optional[float] = 60.0  # infer() deadline
    max_worker_restarts: int = 3
    seed: int = 0                       # backoff-jitter rng


@dataclasses.dataclass
class _Request:
    matrix: Any                # SparseMatrix adjacency
    features: Any              # [n_nodes, d]
    future: Future
    t_submit: float
    attempts: int = 0          # transient retries consumed
    tag: Any = None            # chaos/match + caller bookkeeping label


class BatchServingEngine:
    """Serves a stream of (graph, features) requests with micro-batching.

    Requests enter a bounded queue; a worker thread drains it into
    micro-batches (flushing on ``max_batch`` or the ``max_delay_ms``
    deadline), groups each flush by shape bucket, and executes every
    group as one block-diagonal batch through a
    :class:`repro.batch.BucketedExecutor` — so arbitrary traffic
    compiles O(#buckets) programs and the whole batch rides one planned
    SpMM per model layer.

    ``fn(matrix, h)`` is the per-batch program (default: the planned
    ``matrix @ h``); with ``context`` set (e.g. model weights) it is
    called ``fn(context, matrix, h)`` and the context rides through jit
    as a traced argument shared by every cached executor.  Use
    :meth:`for_gcn` to serve GCN node classification with shared
    weights.
    """

    def __init__(self, fn: Optional[Callable] = None, *,
                 context: Any = None,
                 scfg: Optional[BatchServeConfig] = None):
        from repro.batch import BucketedExecutor
        from repro.batch.bucketing import BucketingConfig

        self.scfg = scfg or BatchServeConfig()
        ladder = None
        if self.scfg.adaptive:
            from repro.serve.runtime.ladder import (AdaptiveBucketLadder,
                                                    LadderConfig)

            lcfg = self.scfg.ladder
            if lcfg is None:
                lcfg = LadderConfig()
            ladder = (lcfg if isinstance(lcfg, AdaptiveBucketLadder)
                      else AdaptiveBucketLadder(lcfg))
        self.executor = BucketedExecutor(
            fn,
            context=context,
            form=self.scfg.form,
            policy=self.scfg.policy,
            max_batch=self.scfg.max_batch,
            max_executors=self.scfg.max_executors,
            bucketing=BucketingConfig(growth=self.scfg.growth),
            ladder=ladder,
        )
        self._queue: "queue_mod.Queue[_Request]" = queue_mod.Queue(
            maxsize=self.scfg.queue_depth)
        self._latencies_ms: List[float] = []
        self._flushes = {"full": 0, "deadline": 0}
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None
        self._submitted = 0
        self._completed = 0
        self._failed = 0
        self._close_lock = threading.Lock()
        self._close_once = threading.Lock()
        self._stop = threading.Event()
        self._rng = np.random.default_rng(self.scfg.seed)
        self._budget = RetryBudget(self.scfg.retry_budget,
                                   self.scfg.retry_refill_per_s)
        self._quarantined = 0
        self._sup = WorkerSupervisor(
            "batch-serve", self._serve_loop,
            max_restarts=self.scfg.max_worker_restarts)
        self._sup.start()

    @property
    def _worker(self) -> threading.Thread:
        """The current serving thread (restarts under the supervisor)."""
        return self._sup._thread

    @classmethod
    def for_gcn(cls, params, *, scfg: Optional[BatchServeConfig] = None,
                ) -> "BatchServingEngine":
        """Engine running a shared-weight GCN over each batch.

        The block-diagonal composition makes the batched forward exact:
        weights are node-independent, so ``diag(A_1..A_N) @ (H W)``
        aggregates every graph at once.
        """
        from repro.models.gnn import Graph, gcn_forward

        cfg = scfg or BatchServeConfig()
        policy, fuse = cfg.policy, cfg.fuse

        def fwd(p, mat, h):
            g = Graph(adj=mat, n_nodes=mat.shape[0])
            return gcn_forward(p, g, h, policy=policy, fuse=fuse)

        # weights enter as the executor context (a jit argument), so the
        # cached per-bucket executables share one copy instead of each
        # baking the params in as XLA constants
        return cls(fwd, context=params, scfg=scfg)

    # -- submission ---------------------------------------------------------

    def submit(self, matrix, features, *, tag: Any = None) -> Future:
        """Enqueue one request; resolves to [n_nodes, d_out] (numpy).

        ``matrix`` is the graph's (normalized) adjacency as a
        ``SparseMatrix`` — or a ``Graph``, whose adjacency is taken.
        Blocks while the admission queue is full (bounded backpressure).
        A dead serving worker is restarted here (bounded by
        ``max_worker_restarts``).
        """
        if self._stop.is_set():
            raise EngineClosedError("engine is closed")
        self._sup.ensure()
        adj = getattr(matrix, "adj", matrix)
        with obs.span("serve.admit", engine="batch"):
            req = _Request(matrix=adj, features=features, future=Future(),
                           t_submit=time.perf_counter(), tag=tag)
            if self._t_first is None:
                self._t_first = req.t_submit
            self._submitted += 1
            self._queue.put(req)
        if self._stop.is_set():
            # close() may have drained between our check and the put;
            # sweep again so no request can strand in a dead queue
            self._fail_queued()
        return req.future

    def infer(self, matrix, features, *,
              timeout: Optional[float] = None) -> np.ndarray:
        """Synchronous convenience wrapper around :meth:`submit`.

        ``timeout`` (default ``scfg.default_timeout_s``) bounds the
        wait; expiry raises :class:`DeadlineExceededError` (a
        :class:`TimeoutError`) instead of blocking forever on a stuck
        future.
        """
        t = self.scfg.default_timeout_s if timeout is None else timeout
        try:
            return self.submit(matrix, features).result(t)
        except DeadlineExceededError:
            raise
        except (TimeoutError, _FutTimeout):
            raise DeadlineExceededError(
                f"infer: no result within {t}s") from None

    # -- worker -------------------------------------------------------------

    def _serve_loop(self) -> None:
        while not self._stop.is_set():
            # chaos fires before any request is picked up, so an
            # injected worker death strands nothing — the supervisor
            # restarts the loop on the next submit()/drain()
            try:
                chaos.hook("serve.worker")
            except chaos.WorkerKilled:
                return  # injected death: the supervisor restarts us
            except Exception:
                continue  # any other injected fault: keep serving
            try:
                first = self._queue.get(timeout=0.05)
            except queue_mod.Empty:
                continue
            batch = [first]
            try:
                self._collect_and_flush(batch)
            except BaseException as exc:  # noqa: BLE001 — worker dying
                # (KeyboardInterrupt, MemoryError, ...) must not strand
                # the futures it already picked up: resolve them with
                # the error before the thread unwinds
                for r in batch:
                    with self._close_lock:
                        self._completed += 1
                        self._failed += 1
                    if not r.future.done() and not r.future.cancelled():
                        r.future.set_exception(
                            RuntimeError(f"serving worker died: {exc!r}"))
                raise

    def _collect_and_flush(self, batch: List[_Request]) -> None:
        # a negative max_delay_ms must degrade to greedy (immediate)
        # flushing, never reach Queue.get as a negative timeout — that
        # raises ValueError, kills the worker thread, and strands every
        # queued future with no error
        window_s = max(self.scfg.max_delay_ms, 0.0) / 1e3
        first = batch[0]
        # the window anchors at the oldest request's *submit* time
        # (queue wait already spent counts against the deadline);
        # requests already queued are always taken — the deadline
        # only bounds how long we *wait* for more
        deadline = first.t_submit + window_s
        while len(batch) < self.scfg.max_batch:
            try:
                batch.append(self._queue.get_nowait())
                continue
            except queue_mod.Empty:
                pass
            # clamped to [0, window]: a slow request — one that sat
            # queued past its whole window while the worker flushed
            # an earlier batch — yields a *negative* remainder and
            # must flush now, not wait; the upper clamp bounds any
            # single wait to one window regardless of timestamp skew
            remaining = min(deadline - time.perf_counter(), window_s)
            if remaining <= 0:
                break
            try:
                batch.append(self._queue.get(timeout=remaining))
            except queue_mod.Empty:
                break
        self._flushes["full" if len(batch) >= self.scfg.max_batch
                      else "deadline"] += 1
        self._flush(batch)

    def _flush(self, batch: List[_Request]) -> None:
        outs, exc = self._try_run(batch)
        if exc is None:
            self._complete(batch, outs)
        else:
            self._recover(batch, exc)

    def _try_run(self, batch: List[_Request]):
        """Execute the batch; returns (outs, None) or (None, exc)."""
        tags = [r.tag for r in batch if r.tag is not None]
        try:
            with obs.span("serve.flush", engine="batch", n=len(batch)):
                chaos.hook("serve.flush", tags=tags, n=len(batch))
                outs = self.executor.run([r.matrix for r in batch],
                                         [r.features for r in batch])
            return outs, None
        except Exception as exc:  # noqa: BLE001 — classified by _recover
            return None, exc

    def _complete(self, batch: List[_Request], outs) -> None:
        t_done = time.perf_counter()
        self._t_last = t_done
        lat_hist = obs.histogram("serve_latency_ms", engine="batch")
        for r, y in zip(batch, outs):
            if self.scfg.guard_nonfinite and not np.isfinite(y).all():
                self._fail_requests([r], NaNOutputError(
                    "non-finite output quarantined "
                    f"(request rows={np.shape(y)[0]})"), quarantine="nan")
                continue
            lat_ms = (t_done - r.t_submit) * 1e3
            self._latencies_ms.append(lat_ms)
            lat_hist.observe(lat_ms)
            with self._close_lock:
                self._completed += 1
            if not r.future.cancelled():
                r.future.set_result(y)

    def _recover(self, batch: List[_Request], exc, *,
                 retried: bool = False) -> None:
        """A flush failed: retry, bisect, quarantine (see DESIGN.md
        "Resilience").  Innocent co-batched requests complete from the
        bisection probes; only the pinned culprit fails."""
        kind = classify(exc)
        if kind == FATAL:
            self._fail_requests(batch, exc)
            return
        if len(batch) == 1:
            r = batch[0]
            if kind == POISON:
                self._fail_requests(batch, exc, quarantine="poison")
                return
            r.attempts += 1
            if self.scfg.retry.allows(r.attempts + 1) \
                    and self._budget.spend():
                obs.counter("resilience_retries_total",
                            site="serve.flush", kind=kind).inc()
                time.sleep(self.scfg.retry.backoff_s(
                    r.attempts + 1, self._rng))
                outs, exc2 = self._try_run(batch)
                if exc2 is None:
                    self._complete(batch, outs)
                else:
                    self._recover(batch, exc2, retried=True)
                return
            self._fail_requests(batch, TransientExecutorError(
                f"retries exhausted after {r.attempts} attempts "
                f"(last error: {exc!r})"))
            return
        if kind == TRANSIENT and not retried and self._budget.spend():
            obs.counter("resilience_retries_total",
                        site="serve.flush", kind=kind).inc()
            time.sleep(self.scfg.retry.backoff_s(2, self._rng))
            outs, exc2 = self._try_run(batch)
            if exc2 is None:
                self._complete(batch, outs)
                return
            exc, kind = exc2, classify(exc2)
            if kind == FATAL:
                self._fail_requests(batch, exc)
                return
        mid = len(batch) // 2
        for half in (batch[:mid], batch[mid:]):
            outs, exc_h = self._try_run(half)
            if exc_h is None:
                self._complete(half, outs)
            else:
                self._recover(half, exc_h, retried=True)

    def _fail_requests(self, batch: List[_Request], exc, *,
                       quarantine: Optional[str] = None) -> None:
        self._t_last = time.perf_counter()
        for r in batch:
            if quarantine is not None:
                self._quarantined += 1
                obs.counter("resilience_quarantined_total",
                            kind=quarantine).inc()
            with self._close_lock:
                self._completed += 1  # resolved (with an error):
                self._failed += 1     # drain must not wait on these
            if not r.future.done() and not r.future.cancelled():
                r.future.set_exception(exc)

    # -- lifecycle ----------------------------------------------------------

    def drain(self, timeout: float = 60.0) -> None:
        """Block until everything submitted so far has completed."""
        t0 = time.perf_counter()
        while self._completed < self._submitted:
            if not self._stop.is_set() and not self._sup.ensure():
                # the worker is dead beyond its restart budget and can
                # never complete the backlog: fail the queued futures
                # now instead of spinning to the timeout
                self._fail_queued()
                if self._completed < self._submitted:
                    raise RuntimeError(
                        "drain: serving worker died with "
                        f"{self._submitted - self._completed} requests "
                        "in flight")
                return
            if time.perf_counter() - t0 > timeout:
                raise TimeoutError(
                    f"drain: {self._submitted - self._completed} requests "
                    f"still pending after {timeout}s")
            time.sleep(0.002)

    def reset_metrics(self) -> None:
        """Zero the traffic counters (e.g. after a warm-up pass).

        Executor state (compiled programs, compile counters) is kept —
        only latency/throughput accounting restarts.  Call with no work
        in flight (after :meth:`drain`).
        """
        if self._completed < self._submitted:
            raise RuntimeError("reset_metrics with requests in flight; "
                               "drain() first")
        self._latencies_ms.clear()
        self._flushes = {"full": 0, "deadline": 0}
        self._t_first = self._t_last = None
        self._submitted = self._completed = self._failed = 0
        self._quarantined = 0

    def _fail_queued(self) -> None:
        """Fail everything still queued so no future blocks forever."""
        while True:
            try:
                req = self._queue.get_nowait()
            except queue_mod.Empty:
                return
            with self._close_lock:
                self._completed += 1
                self._failed += 1
            if not req.future.cancelled():
                req.future.set_exception(EngineClosedError("engine closed"))

    def close(self) -> None:
        """Shut down, leaving no future unresolved.

        Everything admitted before close is *drained* — the worker keeps
        flushing until the queue is empty, so already-submitted requests
        get their results, not an error.  Only if the drain cannot
        finish (dead worker, timeout) are the leftovers failed; either
        way every future resolves and no caller blocks forever.

        Idempotent and safe under concurrent callers: one closer does
        the drain/stop/sweep, later (or racing) closers serialize on
        its lock and find the work done.
        """
        with self._close_once:
            if not self._stop.is_set():
                try:
                    self.drain()
                except Exception:  # noqa: BLE001 — still sweep below
                    pass
            self._stop.set()
            self._sup.join(timeout=5.0)
            self._fail_queued()

    def __enter__(self) -> "BatchServingEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- reporting ----------------------------------------------------------

    def report(self) -> Dict[str, Any]:
        """Throughput, latency percentiles, compile + padding counters.

        Canonical keys (``p50_ms``/``p99_ms``); the pre-PR-8 spellings
        (``latency_ms_p50``/``latency_ms_p99``) resolve via deprecation
        aliases for one cycle.
        """
        lat = np.asarray(self._latencies_ms, np.float64)
        elapsed = ((self._t_last - self._t_first)
                   if (self._t_first is not None
                       and self._t_last is not None) else 0.0)
        return obs.renamed_keys({
            "submitted": self._submitted,
            "completed": self._completed,
            "failed": self._failed,
            "req_per_s": (self._completed / elapsed) if elapsed > 0 else 0.0,
            "p50_ms": float(np.percentile(lat, 50)) if len(lat) else 0.0,
            "p99_ms": float(np.percentile(lat, 99)) if len(lat) else 0.0,
            "flushes": dict(self._flushes),
            "executor": self.executor.report(),
            "resilience": {
                "quarantined": self._quarantined,
                "retry_tokens": self._budget.remaining(),
                "worker_restarts": self._sup.restarts,
            },
        }, {"latency_ms_p50": "p50_ms", "latency_ms_p99": "p99_ms"})


def make_prefill_step(cfg: ModelConfig, max_len: int):
    """Lowerable prefill entry (the prefill_* dry-run cells).

    Takes the batch as a dict so modality side-inputs can never be
    positionally confused (a vision_embeds/enc_embeds swap silently drops
    the whisper encoder — caught by the multi-pod dry-run's out_shardings
    structure check).
    """

    def prefill_step(params, batch):
        kw = {}
        if cfg.vision_tokens and "vision_embeds" in batch:
            kw["vision_embeds"] = batch["vision_embeds"]
        if cfg.encoder_layers and "enc_embeds" in batch:
            kw["enc_embeds"] = batch["enc_embeds"]
        logits, cache = prefill(params, cfg, batch["tokens"], max_len, **kw)
        return logits, cache

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """Lowerable single-token decode entry (the decode_* dry-run cells)."""

    def serve_step(params, token, cache):
        return decode_step(params, cfg, token, cache)

    return serve_step
