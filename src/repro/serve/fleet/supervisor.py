"""Process-level supervision state for the serving fleet.

:class:`~repro.resilience.supervisor.WorkerSupervisor` restarts a dead
*thread* atomically under a lock — spawn is microseconds, so observe-
dead → charge-budget → respawn can all hold the mutex.  A fleet worker
is a process: a respawn imports jax and pre-compiles lanes, which takes
seconds and must not block the lock.  The guard therefore splits in
two, the same generation pattern ``WorkerSupervisor.ensure()`` exposes:

* :meth:`FleetSupervisor.begin_death` atomically claims a death — it
  checks the observer's *generation* against the current one and flips
  the state to ``dead`` + ``restarting=True`` under the lock.  Exactly
  one of the racing observers (a pump thread seeing EOF, the monitor
  seeing ``alive() == False``, the heartbeat timeout) wins; the rest
  get ``None`` and walk away.  Double-restart and double-charging the
  budget are structurally impossible, not just unlikely.
* The winner respawns **outside** the lock, then calls
  :meth:`finish_restart` (or :meth:`abandon_restart` when the budget is
  spent) to publish the new generation.

Liveness signals feed :mod:`repro.ft.health`: each worker's heartbeats
go through a shared :class:`~repro.ft.health.Heartbeat` (missed-beat
detection) and its per-request service times through a per-worker
:class:`~repro.ft.health.StragglerDetector` — a worker that is alive
but slow gets flagged, and the fleet hedges its oldest request instead
of killing it.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Set

from repro import obs
from repro.ft.health import Heartbeat, HealthConfig, StragglerDetector

#: worker lifecycle states
WARMING = "warming"    # spawned, compiling hot lanes; not routable yet
LIVE = "live"          # in the rotation
DRAINING = "draining"  # finishing in-flight, no new work (scale-down)
DEAD = "dead"          # observed dead; restart may be in flight
RETIRED = "retired"    # deliberately stopped; never restarted


@dataclasses.dataclass
class WorkerState:
    """One worker slot's supervision record (mutated under the lock)."""

    name: str
    handle: Any = None
    status: str = WARMING
    generation: int = 1
    restarts: int = 0
    served: int = 0
    pump: Any = None  # the pump thread draining this handle


class FleetSupervisor:
    """Registry + liveness/straggler bookkeeping for fleet workers."""

    def __init__(self, *, lock, health: Optional[HealthConfig] = None,
                 max_restarts_per_worker: int = 2):
        self._lock = lock
        self.health = health if health is not None else HealthConfig()
        self.max_restarts_per_worker = int(max_restarts_per_worker)
        self.workers: Dict[str, WorkerState] = {}
        self.hb = Heartbeat(self.health)
        self.detectors: Dict[str, StragglerDetector] = {}
        self.stragglers: Set[str] = set()

    # -- registry -----------------------------------------------------------

    def register(self, ws: WorkerState) -> None:
        with self._lock:
            self.workers[ws.name] = ws
            self.detectors.setdefault(ws.name,
                                      StragglerDetector(self.health))
        self.hb.beat(ws.name)  # spawn grace: not dead before first beat
        self._gauge()

    def live(self) -> List[str]:
        """Routable workers, in insertion order (determinism)."""
        with self._lock:
            return [n for n, ws in self.workers.items()
                    if ws.status == LIVE]

    def states(self) -> List[WorkerState]:
        with self._lock:
            return list(self.workers.values())

    def get(self, name: str) -> Optional[WorkerState]:
        with self._lock:
            return self.workers.get(name)

    def counts(self) -> Dict[str, int]:
        with self._lock:
            out: Dict[str, int] = {}
            for ws in self.workers.values():
                out[ws.status] = out.get(ws.status, 0) + 1
            return out

    def _gauge(self) -> None:
        counts = self.counts()
        obs.gauge("fleet_workers_live").set(counts.get(LIVE, 0))
        obs.gauge("fleet_workers_total").set(
            sum(v for k, v in counts.items() if k != RETIRED))

    # -- liveness signals ---------------------------------------------------

    def note_heartbeat(self, name: str, generation: int) -> None:
        with self._lock:
            ws = self.workers.get(name)
            if ws is None or ws.generation != generation:
                return  # a dead generation's leftover beat
        self.hb.beat(name)

    def note_service_time(self, name: str, dt_s: float) -> bool:
        """Record one request's service time; True marks a straggler."""
        with self._lock:
            det = self.detectors.get(name)
        if det is None:
            return False
        flagged = det.record(step=0, dt=dt_s)
        if flagged:
            self.stragglers.add(name)
            obs.counter("fleet_stragglers_total", worker=name).inc()
        return flagged

    def heartbeat_dead(self, now: Optional[float] = None) -> List[str]:
        """Live/warming workers whose heartbeats timed out."""
        dead = self.hb.dead_hosts(now)
        with self._lock:
            return [n for n in dead
                    if n in self.workers
                    and self.workers[n].status in (LIVE, WARMING, DRAINING)]

    # -- the split death/restart guard --------------------------------------

    def begin_death(self, name: str, observed_generation: int
                    ) -> Optional[WorkerState]:
        """Atomically claim a worker's death.  Returns the state when
        this caller won (status flipped to DEAD, restart claimed) or
        ``None`` when someone else already handled this generation's
        death — the process-level analog of
        ``WorkerSupervisor.ensure(observed_generation=...)``."""
        with self._lock:
            ws = self.workers.get(name)
            if ws is None or ws.generation != observed_generation:
                return None
            if ws.status in (DEAD, RETIRED):
                return None
            ws.status = DEAD
        self.hb.forget(name)
        self.stragglers.discard(name)
        self._gauge()
        return ws

    def may_restart(self, ws: WorkerState) -> bool:
        with self._lock:
            return ws.restarts < self.max_restarts_per_worker

    def finish_restart(self, ws: WorkerState, handle, pump) -> int:
        """Publish a respawned worker: bump generation, charge budget.
        Returns the new generation."""
        with self._lock:
            ws.restarts += 1
            ws.generation += 1
            ws.handle = handle
            ws.pump = pump
            ws.status = WARMING
            gen = ws.generation
        obs.counter("fleet_restarts_total", worker=ws.name).inc()
        obs.counter("resilience_recoveries_total", site="fleet").inc()
        self.hb.beat(ws.name)
        self._gauge()
        return gen

    def abandon_restart(self, ws: WorkerState) -> None:
        """Budget exhausted: the slot stays DEAD for good."""
        self._gauge()

    # -- deliberate transitions ---------------------------------------------

    def set_status(self, name: str, status: str,
                   generation: Optional[int] = None) -> bool:
        with self._lock:
            ws = self.workers.get(name)
            if ws is None:
                return False
            if generation is not None and ws.generation != generation:
                return False
            if ws.status in (DEAD, RETIRED) and status == LIVE:
                return False  # a ready message from a killed generation
            ws.status = status
        if status == RETIRED:
            self.hb.forget(name)
            self.stragglers.discard(name)
        self._gauge()
        return True


__all__ = [
    "DEAD", "DRAINING", "FleetSupervisor", "LIVE", "RETIRED", "WARMING",
    "WorkerState",
]
