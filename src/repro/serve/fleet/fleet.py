"""The serving fleet: supervised multi-worker serving with failover.

:class:`ServingFleet` fronts N workers (threads or real ``spawn``
processes, see :mod:`repro.serve.fleet.rpc`) behind one submit/infer
surface that speaks the same futures-and-taxonomy contract as the
single-process engines.  The moving parts:

* the :class:`~repro.serve.fleet.router.Router` journals every request
  and places it with lane-sticky round-robin (warm-executor locality);
* per-worker **pump threads** drain results and heartbeats;
* a **monitor thread** runs the control loop: crash + missed-heartbeat
  detection (via :mod:`repro.ft.health`), failover of a dead worker's
  in-flight to survivors (at-most-once through the journal), bounded
  respawns that re-warm the hot lanes before rejoining the rotation,
  straggler hedging with first-wins cancellation, unrouted re-drive,
  scale-down retirement, and the
  :class:`~repro.serve.fleet.autoscale.Autoscaler` decisions;
* all four chaos sites (``fleet.worker``, ``fleet.heartbeat``,
  ``fleet.rpc`` at send and recv) fire **parent-side**, so a seeded
  :class:`~repro.resilience.chaos.FaultPlan` replays deterministically
  even over real child processes that never see the plan.

Failure semantics: a request fails with
:class:`~repro.resilience.errors.WorkerLostError` only when every
worker slot is dead with its restart budget spent; anything short of
that re-routes.  ``close()`` drains, then stops workers, then fails
whatever could not complete with ``EngineClosedError`` — no future is
ever left unresolved, and both ``close()`` and ``submit``-after-close
are safe from any thread at any point of the lifecycle.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from concurrent.futures import Future
from concurrent.futures import TimeoutError as _FuturesTimeout
from typing import Any, Dict, List, Optional

import numpy as np

from repro import obs
from repro.ft.health import HealthConfig
from repro.resilience import chaos
from repro.resilience.errors import (DeadlineExceededError,
                                     EngineClosedError, WorkerLostError)
from repro.serve.fleet import rpc
from repro.serve.fleet.autoscale import AutoscaleConfig, Autoscaler
from repro.serve.fleet.router import Router
from repro.serve.fleet.supervisor import (DEAD, DRAINING, LIVE, RETIRED,
                                          WARMING, FleetSupervisor,
                                          WorkerState)
from repro.serve.fleet.worker import WorkerConfig


@dataclasses.dataclass
class FleetConfig:
    """Fleet topology + supervision cadence.

    Dataclass-instance knobs (``worker``, ``health``, ``autoscale``)
    default to ``None`` and are built per-instance in ``__post_init__``
    — a shared default instance would alias config state across fleets
    (see the mutable-default audit in tests/test_fleet.py).
    """

    backend: str = "thread"        # "thread" | "process"
    workers: int = 2               # initial fleet size
    worker: Optional[WorkerConfig] = None
    health: Optional[HealthConfig] = None
    autoscale: Optional[AutoscaleConfig] = None
    max_restarts_per_worker: int = 2
    monitor_interval_s: float = 0.005
    rpc_poll_s: float = 0.02       # pump blocking-poll quantum
    hedge_after_ms: float = 250.0  # absolute hedge trigger
    straggler_hedge_scale: float = 0.25  # flagged workers hedge sooner
    rebalance_factor: float = 4.0
    warm_lanes: int = 2            # hot lanes pre-compiled on (re)spawn
    drain_timeout_s: float = 30.0
    ready_timeout_s: float = 60.0
    name_prefix: str = "w"

    def __post_init__(self):
        if self.worker is None:
            self.worker = WorkerConfig()
        if self.health is None:
            # per-backend heartbeat deadline: thread workers beat every
            # ~20ms, process workers pay jax import + compiles on spawn
            timeout = 0.5 if self.backend == "thread" else 5.0
            self.health = HealthConfig(heartbeat_timeout_s=timeout)
        if self.autoscale is None:
            self.autoscale = AutoscaleConfig()


class ServingFleet:
    """Fault-tolerant multi-worker serving engine (module docstring)."""

    def __init__(self, cfg: Optional[FleetConfig] = None):
        self.cfg = cfg if cfg is not None else FleetConfig()
        self._lock = threading.RLock()
        self._close_once = threading.Lock()
        self._closing = False
        self._closed = False
        self._stop_evt = threading.Event()
        self.sup = FleetSupervisor(
            lock=self._lock, health=self.cfg.health,
            max_restarts_per_worker=self.cfg.max_restarts_per_worker)
        self.router = Router(
            send=self._send, live=self.sup.live, lock=self._lock,
            rebalance_factor=self.cfg.rebalance_factor)
        self.scaler = Autoscaler(self.cfg.autoscale)
        self._latencies_ms: List[float] = []
        self._submitted = 0
        self._completed = 0
        self._failed = 0
        self._lost = 0
        self._waiters: Dict[int, List[Any]] = {}  # token -> [event, value]
        self._tokens = itertools.count(1)
        self._worker_seq = itertools.count(1)
        self._readies: Dict[str, int] = {}  # readies outstanding per worker
        for _ in range(max(1, int(self.cfg.workers))):
            self._spawn_worker()
        self._monitor = threading.Thread(
            target=self._monitor_loop, daemon=True, name="fleet-monitor")
        self._monitor.start()

    # ------------------------------------------------------------------
    # spawning
    # ------------------------------------------------------------------

    def _spawn_worker(self, warm: Optional[List[Dict[str, Any]]] = None
                      ) -> WorkerState:
        name = f"{self.cfg.name_prefix}{next(self._worker_seq)}"
        handle = rpc.make_handle(self.cfg.backend, name, self.cfg.worker)
        ws = WorkerState(name=name, handle=handle)
        with self._lock:
            self._readies[name] = 1
        self.sup.register(ws)
        if warm:
            with self._lock:
                self._readies[name] = 2
            try:
                handle.send(("warm", warm))
            except rpc.TransportError:
                with self._lock:
                    self._readies[name] = 1
        pump = threading.Thread(
            target=self._pump_loop, args=(name, ws.generation, handle),
            daemon=True, name=f"fleet-pump-{name}")
        with self._lock:
            ws.pump = pump
        pump.start()
        obs.counter("fleet_workers_spawned_total").inc()
        return ws

    # ------------------------------------------------------------------
    # transport (all chaos fires here, parent-side)
    # ------------------------------------------------------------------

    def _send(self, name: str, msg: rpc.Message) -> bool:
        ws = self.sup.get(name)
        if ws is None or ws.handle is None or ws.status in (DEAD, RETIRED):
            return False
        gen = ws.generation
        try:
            chaos.hook("fleet.rpc", worker=name, phase="send")
        except chaos.ProcessKillRequested:
            self._kill_worker(name)
            return False
        except chaos.WorkerHangRequested:
            return True  # blackholed: claimed sent, never delivered
        except Exception:  # noqa: BLE001 — injected transient send fault
            obs.counter("fleet_rpc_faults_total", phase="send").inc()
            return False
        try:
            ws.handle.send(msg)
        except rpc.TransportError:
            self._worker_down(name, gen, "send")
            return False
        if msg[0] == "req":
            # dispatch-site chaos: a plan can kill/hang this worker
            # deterministically right after its Nth request lands —
            # the "mid-batch kill" the acceptance storm uses.  The
            # request WAS delivered, so failover must recover it.
            try:
                chaos.hook("fleet.worker", worker=name, phase="dispatch")
            except chaos.ProcessKillRequested:
                self._kill_worker(name)
            except chaos.WorkerHangRequested as h:
                self._hang_worker(name, h.payload)
            except Exception:  # noqa: BLE001 — other kinds are no-ops here
                pass
        return True

    def _kill_worker(self, name: str) -> None:
        ws = self.sup.get(name)
        if ws is None:
            return
        gen = ws.generation
        obs.counter("fleet_kills_total", worker=name).inc()
        try:
            ws.handle.kill()
        except Exception:  # noqa: BLE001
            pass
        self._worker_down(name, gen, "killed")

    def _hang_worker(self, name: str, seconds: Optional[float]) -> None:
        ws = self.sup.get(name)
        if ws is None:
            return
        try:
            ws.handle.send(("hang", seconds))
        except rpc.TransportError:
            pass

    # ------------------------------------------------------------------
    # death → failover → bounded respawn
    # ------------------------------------------------------------------

    def _worker_down(self, name: str, observed_gen: int, reason: str
                     ) -> None:
        ws = self.sup.begin_death(name, observed_gen)
        if ws is None:
            return  # another observer already claimed this death
        obs.counter("fleet_worker_deaths_total",
                    worker=name, reason=reason).inc()
        try:
            ws.handle.kill()  # a hung worker is alive; make it not be
        except Exception:  # noqa: BLE001
            pass
        for entry in self.router.orphans_of(name):
            obs.counter("fleet_failovers_total").inc()
            self.router.dispatch(entry, exclude=(name,))
        if not self._closing and self.sup.may_restart(ws):
            handle = rpc.make_handle(self.cfg.backend, name,
                                     self.cfg.worker)
            gen = self.sup.finish_restart(ws, handle, pump=None)
            warm = self.router.hot_lanes(self.cfg.warm_lanes)
            with self._lock:
                self._readies[name] = 1
            if warm:
                with self._lock:
                    self._readies[name] = 2
                try:
                    handle.send(("warm", warm))
                except rpc.TransportError:
                    with self._lock:
                        self._readies[name] = 1
            pump = threading.Thread(
                target=self._pump_loop, args=(name, gen, handle),
                daemon=True, name=f"fleet-pump-{name}-g{gen}")
            with self._lock:
                ws.pump = pump
            pump.start()
        else:
            self.sup.abandon_restart(ws)
            self._strand_check()

    def _strand_check(self) -> None:
        """With no worker slot able to serve, pending futures must not
        hang forever: fail them with WorkerLostError (counted lost)."""
        counts = self.sup.counts()
        if counts.get(LIVE, 0) + counts.get(WARMING, 0) \
                + counts.get(DRAINING, 0) > 0:
            return
        for entry in self.router.pending_entries():
            if self.router.fail(entry, WorkerLostError(
                    "all fleet workers dead, restart budget exhausted")):
                with self._lock:
                    self._lost += 1
                    self._failed += 1
                obs.counter("fleet_requests_lost_total").inc()

    # ------------------------------------------------------------------
    # pump: one thread per worker generation
    # ------------------------------------------------------------------

    def _pump_loop(self, name: str, gen: int, handle) -> None:
        while not self._stop_evt.is_set():
            ws = self.sup.get(name)
            if ws is None or ws.generation != gen or ws.status == RETIRED:
                return
            try:
                msg = handle.poll(self.cfg.rpc_poll_s)
            except rpc.TransportError:
                self._worker_down(name, gen, "transport")
                return
            if msg is None:
                if ws.status != DEAD and not handle.alive():
                    self._worker_down(name, gen, "exit")
                    return
                continue
            try:
                chaos.hook("fleet.rpc", worker=name, phase="recv")
            except chaos.ProcessKillRequested:
                self._kill_worker(name)
                continue
            except chaos.WorkerHangRequested:
                continue  # frame blackholed
            except Exception:  # noqa: BLE001 — injected recv fault
                obs.counter("fleet_rpc_faults_total", phase="recv").inc()
                continue
            self._on_message(name, gen, msg)

    def _on_message(self, name: str, gen: int, msg: rpc.Message) -> None:
        kind = msg[0]
        if kind == "hb":
            try:
                chaos.hook("fleet.heartbeat", worker=name)
            except chaos.ProcessKillRequested:
                self._kill_worker(name)
                return
            except Exception:  # noqa: BLE001 — hang/raise: the beat is
                return         # lost; delay slept above = a late beat
            self.sup.note_heartbeat(name, gen)
        elif kind == "res":
            # results from a freshly-dead generation still count: the
            # journal dedupes against the failover re-execution
            self._on_result(name, msg)
        elif kind == "ready":
            self._on_ready(name, gen)
        elif kind in ("report_res", "drained"):
            token = msg[1]
            with self._lock:
                waiter = self._waiters.get(token)
                if waiter is not None:
                    waiter[1] = msg[2] if len(msg) > 2 else True
                    waiter[0].set()
        elif kind == "bye":
            ws = self.sup.get(name)
            if ws is not None and ws.generation == gen \
                    and ws.status not in (RETIRED, DEAD):
                if self._closing or ws.status == DRAINING:
                    self.sup.set_status(name, RETIRED, generation=gen)
                else:
                    self._worker_down(name, gen, "bye")

    def _on_result(self, src: str, msg: rpc.Message) -> None:
        _, rid, ok, value = msg
        res = self.router.complete(rid, ok, value, src)
        if res is None:
            return  # duplicate (late pipe / hedge loser) — dropped
        entry, other = res
        now = time.monotonic()
        lat_ms = (now - entry.t_submit) * 1e3
        with self._lock:
            self._latencies_ms.append(lat_ms)
            if len(self._latencies_ms) > 8192:
                del self._latencies_ms[:4096]
            self._completed += 1
            if not ok:
                self._failed += 1
            ws = self.sup.workers.get(src)
            if ws is not None:
                ws.served += 1
        obs.histogram("fleet_latency_ms").observe(lat_ms)
        if entry.t_dispatch:
            self.sup.note_service_time(src, now - entry.t_dispatch)
        if other is not None:
            obs.counter("fleet_hedge_cancels_total").inc()
            try:
                ows = self.sup.get(other)
                if ows is not None and ows.status not in (DEAD, RETIRED):
                    ows.handle.send(("cancel", rid))
            except rpc.TransportError:
                pass

    def _on_ready(self, name: str, gen: int) -> None:
        with self._lock:
            left = max(0, self._readies.get(name, 1) - 1)
            self._readies[name] = left
        if left > 0:
            return  # engine is up; still warming hot lanes
        if self.sup.set_status(name, LIVE, generation=gen):
            for entry in self.router.take_unrouted():
                self.router.dispatch(entry)

    # ------------------------------------------------------------------
    # monitor: the control loop
    # ------------------------------------------------------------------

    def _monitor_loop(self) -> None:
        while not self._stop_evt.is_set():
            time.sleep(self.cfg.monitor_interval_s)
            try:
                self._tick()
            except Exception:  # noqa: BLE001 — the loop must survive
                obs.counter("fleet_monitor_errors_total").inc()

    def _tick(self) -> None:
        now = time.monotonic()
        for ws in self.sup.states():
            if ws.status in (DEAD, RETIRED):
                continue
            name, gen = ws.name, ws.generation
            try:
                chaos.hook("fleet.worker", worker=name, phase="monitor")
            except chaos.ProcessKillRequested:
                self._kill_worker(name)
                continue
            except chaos.WorkerHangRequested as h:
                self._hang_worker(name, h.payload)
                continue
            except Exception:  # noqa: BLE001
                pass
            if ws.handle is not None and not ws.handle.alive():
                self._worker_down(name, gen, "exit")
        for name in self.sup.heartbeat_dead(now):
            ws = self.sup.get(name)
            if ws is not None:
                self._worker_down(name, ws.generation, "heartbeat")
        # hedging: stragglers hedge at a fraction of the age threshold
        base_s = self.cfg.hedge_after_ms / 1e3
        for name in self.sup.live():
            age = base_s * (self.cfg.straggler_hedge_scale
                            if name in self.sup.stragglers else 1.0)
            entry = self.router.hedge_candidate(name, age)
            if entry is not None:
                self.router.hedge(entry)
        if self.sup.live():
            for entry in self.router.take_unrouted():
                self.router.dispatch(entry)
        for ws in self.sup.states():
            if ws.status == DRAINING \
                    and not self.router.inflight.get(ws.name):
                self._retire(ws)
        decision = self.scaler.decide(
            now, pending=self.router.pending(),
            live_workers=len(self.sup.live()),
            p99_ms=self._recent_p99())
        if decision == "up" and not self._closing:
            obs.counter("fleet_scale_ups_total").inc()
            self._spawn_worker(warm=self.router.hot_lanes(
                self.cfg.warm_lanes))
        elif decision == "down" and not self._closing:
            live = self.sup.live()
            if len(live) > 1:
                victim = min(live,
                             key=lambda n: (len(self.router.inflight[n]), n))
                obs.counter("fleet_scale_downs_total").inc()
                self.sup.set_status(victim, DRAINING)

    def _retire(self, ws: WorkerState) -> None:
        try:
            ws.handle.send(("stop",))
        except rpc.TransportError:
            pass
        self.sup.set_status(ws.name, RETIRED)
        obs.counter("fleet_workers_retired_total").inc()

    def _recent_p99(self) -> Optional[float]:
        with self._lock:
            if len(self._latencies_ms) < 8:
                return None
            tail = np.asarray(self._latencies_ms[-256:], np.float64)
        return float(np.percentile(tail, 99))

    # ------------------------------------------------------------------
    # public surface
    # ------------------------------------------------------------------

    def submit(self, matrix, features, *, steps: int = 1,
               tag: Any = None) -> Future:
        """Admit one request; resolves to [n_nodes, d_out] (numpy) or
        fails with a taxonomy error.  Survives worker deaths."""
        if self._closing or self._closed:
            raise EngineClosedError("fleet is closed")
        payload = rpc.encode_request(matrix, features, steps)
        entry = self.router.admit(payload, tag=tag)
        with self._lock:
            self._submitted += 1
        obs.counter("fleet_requests_total").inc()
        self.router.dispatch(entry)
        return entry.future

    def infer(self, matrix, features, *, steps: int = 1,
              timeout: Optional[float] = 30.0) -> np.ndarray:
        fut = self.submit(matrix, features, steps=steps)
        try:
            return fut.result(timeout=timeout)
        except _FuturesTimeout:
            raise DeadlineExceededError(
                f"fleet.infer timed out after {timeout}s") from None

    def pending(self) -> int:
        return self.router.pending()

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every admitted request resolved (failover and
        respawns keep running underneath)."""
        deadline = time.monotonic() + (
            timeout if timeout is not None else self.cfg.drain_timeout_s)
        while self.router.pending() > 0:
            if time.monotonic() > deadline:
                raise DeadlineExceededError(
                    f"fleet drain timed out with "
                    f"{self.router.pending()} pending")
            time.sleep(0.002)

    def wait_live(self, n: int = 1, timeout: Optional[float] = None
                  ) -> bool:
        """Block until ``n`` workers are in the rotation."""
        deadline = time.monotonic() + (
            timeout if timeout is not None else self.cfg.ready_timeout_s)
        while len(self.sup.live()) < n:
            if time.monotonic() > deadline:
                return False
            time.sleep(0.002)
        return True

    def rolling_restart(self, timeout_per_worker: float = 60.0) -> None:
        """Replace every worker one at a time without dropping requests:
        spawn a warm successor, wait for it to join, drain + retire the
        old worker, repeat."""
        for ws in self.sup.states():
            if ws.status not in (LIVE, WARMING):
                continue
            if self._closing:
                return
            new_ws = self._spawn_worker(
                warm=self.router.hot_lanes(self.cfg.warm_lanes))
            deadline = time.monotonic() + timeout_per_worker
            while True:
                st = self.sup.get(new_ws.name)
                if st is not None and st.status == LIVE:
                    break
                if time.monotonic() > deadline:
                    break
                time.sleep(0.002)
            self.sup.set_status(ws.name, DRAINING)
            while self.router.inflight.get(ws.name) \
                    and time.monotonic() < deadline:
                time.sleep(0.002)
            cur = self.sup.get(ws.name)
            if cur is not None and cur.status == DRAINING:
                self._retire(cur)
        obs.counter("fleet_rolling_restarts_total").inc()

    def close(self, timeout: Optional[float] = None) -> None:
        """Drain, stop the fleet, fail anything unresolved.  Idempotent
        and safe to race with worker deaths."""
        with self._close_once:
            if self._closed:
                return
            self._closing = True
            try:
                self.drain(timeout if timeout is not None
                           else self.cfg.drain_timeout_s)
            except Exception:  # noqa: BLE001 — leftovers failed below
                pass
            self._stop_evt.set()
            for ws in self.sup.states():
                if ws.status in (DEAD, RETIRED):
                    continue
                try:
                    ws.handle.send(("stop",))
                except rpc.TransportError:
                    pass
            deadline = time.monotonic() + 2.0
            for ws in self.sup.states():
                if ws.handle is None:
                    continue
                ws.handle.join(timeout=max(0.0,
                                           deadline - time.monotonic()))
                if ws.handle.alive():
                    try:
                        ws.handle.kill()
                    except Exception:  # noqa: BLE001
                        pass
            for entry in self.router.pending_entries():
                if self.router.fail(entry, EngineClosedError(
                        "fleet closed before this request completed")):
                    with self._lock:
                        self._lost += 1
                        self._failed += 1
                    obs.counter("fleet_requests_lost_total").inc()
            self._closed = True

    def __enter__(self) -> "ServingFleet":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------
    # reporting
    # ------------------------------------------------------------------

    def _collect_worker_reports(self, timeout: float = 1.0
                                ) -> Dict[str, Dict[str, Any]]:
        tokens: Dict[str, int] = {}
        for name in self.sup.live():
            token = next(self._tokens)
            with self._lock:
                self._waiters[token] = [threading.Event(), None]
            if self._send(name, ("report", token)):
                tokens[name] = token
            else:
                with self._lock:
                    self._waiters.pop(token, None)
        out: Dict[str, Dict[str, Any]] = {}
        deadline = time.monotonic() + timeout
        for name, token in tokens.items():
            with self._lock:
                waiter = self._waiters.get(token)
            if waiter is None:
                continue
            waiter[0].wait(timeout=max(0.0, deadline - time.monotonic()))
            with self._lock:
                self._waiters.pop(token, None)
            if waiter[1] is not None:
                out[name] = waiter[1]
        return out

    def report(self) -> Dict[str, Any]:
        """Fleet-level canonical keys (p50_ms/p99_ms/waste) + per-worker
        engine reports + the ``fleet`` supervision section."""
        worker_reports = self._collect_worker_reports()
        with self._lock:
            lat = np.asarray(self._latencies_ms, np.float64)
            submitted, completed = self._submitted, self._completed
            failed, lost = self._failed, self._lost
        waste_num = waste_den = 0.0
        for rep in worker_reports.values():
            ex = rep.get("executor") or {}
            calls = float(ex.get("calls", 0) or 0)
            frac = ((ex.get("waste") or {}).get("waste_fraction", 0.0)
                    or 0.0)
            waste_num += calls * float(frac)
            waste_den += calls
        workers = {}
        for ws in self.sup.states():
            workers[ws.name] = {
                "status": ws.status,
                "generation": ws.generation,
                "restarts": ws.restarts,
                "served": ws.served,
                "inflight": len(self.router.inflight.get(ws.name, ())),
            }
        return obs.renamed_keys({
            "submitted": submitted,
            "completed": completed,
            "failed": failed,
            "pending": self.router.pending(),
            "p50_ms": (float(np.percentile(lat, 50)) if len(lat) else 0.0),
            "p99_ms": (float(np.percentile(lat, 99)) if len(lat) else 0.0),
            "waste": (waste_num / waste_den) if waste_den else 0.0,
            "workers": workers,
            "worker_reports": worker_reports,
            "fleet": {
                "backend": self.cfg.backend,
                "live": len(self.sup.live()),
                "requests_lost": lost,
                "unrouted": len(self.router.unrouted),
                "lanes": {f"{b}/d{d}": owner for (b, d), owner
                          in self.router.lane_owner.items()},
            },
        }, {"latency_ms_p50": "p50_ms", "latency_ms_p99": "p99_ms"})


__all__ = ["FleetConfig", "ServingFleet"]
