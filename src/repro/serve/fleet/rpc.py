"""Transport shim between the fleet parent and its workers.

One protocol, two carriers:

* :class:`ThreadHandle` — the worker loop runs on a daemon thread in
  this process, messages move over two in-process queues, and ``kill()``
  flips an event the worker polls (simulating SIGKILL: the loop stops
  mid-iteration and anything it had not yet sent is lost).  This is the
  deterministic backend tier-1 tests use.
* :class:`ProcessHandle` — the worker runs in a real ``spawn`` child
  process with two ``multiprocessing`` queues, and ``kill()`` is an
  actual SIGKILL.  Same protocol, real failure surface; exercised by
  the slow tests, the fleet benchmark, and the CI soak.

Messages are plain picklable tuples (``(kind, *args)``):

====================================  ====================================
parent → worker                       worker → parent
====================================  ====================================
``("req", rid, payload)``             ``("ready",)`` — warmup done
``("cancel", rid)``                   ``("hb", seq, pending)``
``("warm", [payload, ...])``          ``("res", rid, ok, value)``
``("hang", seconds | None)``          ``("report_res", token, report)``
``("report", token)``                 ``("drained", token)``
``("drain", token)``                  ``("bye",)`` — clean exit
``("stop",)``
====================================  ====================================

``payload`` is the :func:`encode_request` dict (dense adjacency +
features + steps, all numpy) — workers rebuild the
:class:`~repro.sparse.matrix.SparseMatrix` themselves, so nothing
jax-specific crosses the pipe.  A failed request's ``value`` is the
:func:`encode_error` pair, decoded parent-side against the
:mod:`repro.resilience.errors` taxonomy.
"""
from __future__ import annotations

import multiprocessing as mp
import queue as queue_mod
import threading
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.resilience import errors as _errors

Message = Tuple[Any, ...]


class TransportError(RuntimeError):
    """The carrier to/from a worker is broken (dead process, closed
    pipe, unpicklable frame).  The fleet treats it as a worker death."""


# ---------------------------------------------------------------------------
# Request / error codecs
# ---------------------------------------------------------------------------


def encode_request(matrix, features, steps: int = 1) -> Dict[str, Any]:
    """Flatten a request to numpy so it survives pickling to a worker.

    ``matrix`` may be a SparseMatrix (or anything with ``to_dense()``),
    a Graph-like object carrying ``.matrix``, or a dense array.
    """
    if hasattr(matrix, "matrix"):  # Graph-like wrapper
        matrix = matrix.matrix
    if hasattr(matrix, "to_dense"):
        dense = np.asarray(matrix.to_dense(), dtype=np.float32)
    else:
        dense = np.asarray(matrix, dtype=np.float32)
    return {"dense": dense,
            "h": np.asarray(features, dtype=np.float32),
            "steps": int(steps)}


def decode_request(payload: Dict[str, Any], *, formats=("ell", "csr"),
                   block=(16, 16)):
    """Worker-side: rebuild (SparseMatrix, features, steps)."""
    from repro.sparse.matrix import SparseMatrix
    mat = SparseMatrix.from_dense(payload["dense"], formats=tuple(formats),
                                  block=tuple(block))
    return mat, payload["h"], payload["steps"]


def lane_key(payload: Dict[str, Any]) -> Tuple[int, int]:
    """Affinity key of a request: (pow2-quantized rows, feature dim).

    Matches the engine's bucket quantization closely enough that two
    requests with equal keys land in the same compiled lane, which is
    what router stickiness exists to exploit.
    """
    rows = int(payload["dense"].shape[0])
    d = int(payload["h"].shape[1])
    b = 1
    while b < rows:
        b <<= 1
    return (b, d)


def encode_error(exc: BaseException) -> Tuple[str, str]:
    return (type(exc).__name__, str(exc))


def decode_error(pair: Tuple[str, str]) -> Exception:
    """Map a (class-name, message) pair back onto the taxonomy; unknown
    names decode as TransientExecutorError (the safe retry class)."""
    name, msg = pair
    cls = getattr(_errors, name, None)
    if isinstance(cls, type) and issubclass(cls, BaseException):
        return cls(msg)
    return _errors.TransientExecutorError(f"{name}: {msg}")


# ---------------------------------------------------------------------------
# Worker-side endpoint (constructed inside the worker thread/process)
# ---------------------------------------------------------------------------


class Endpoint:
    """The worker's two-way view of its carrier."""

    def __init__(self, inbox, outbox, killed=None):
        self._in = inbox
        self._out = outbox
        self._killed = killed or (lambda: False)

    def recv(self, timeout: float) -> Optional[Message]:
        try:
            return self._in.get(timeout=timeout)
        except queue_mod.Empty:
            return None

    def send(self, msg: Message) -> None:
        if self._killed():
            return  # a SIGKILLed process can't speak either
        self._out.put(msg)

    def killed(self) -> bool:
        return self._killed()


# ---------------------------------------------------------------------------
# Parent-side handles
# ---------------------------------------------------------------------------


class ThreadHandle:
    """In-process worker on a daemon thread; ``kill()`` flips an event
    the worker polls every iteration — messages already queued outbound
    may still arrive (exactly like a real kill racing the pipe), which
    is why the router's journal dedupes completions."""

    backend = "thread"

    def __init__(self, name: str, worker_cfg) -> None:
        from repro.serve.fleet.worker import FleetWorker
        self.name = name
        self._in: queue_mod.Queue = queue_mod.Queue()
        self._out: queue_mod.Queue = queue_mod.Queue()
        self._kill_evt = threading.Event()
        ep = Endpoint(self._in, self._out, self._kill_evt.is_set)
        worker = FleetWorker(worker_cfg, name=name)
        self._thread = threading.Thread(
            target=worker.run, args=(ep,), daemon=True,
            name=f"fleet-{name}")
        self._thread.start()

    @property
    def pid(self) -> Optional[int]:
        return None

    def send(self, msg: Message) -> None:
        if self._kill_evt.is_set():
            raise TransportError(f"worker {self.name} is killed")
        self._in.put(msg)

    def poll(self, timeout: float) -> Optional[Message]:
        try:
            return self._out.get(timeout=timeout)
        except queue_mod.Empty:
            return None

    def alive(self) -> bool:
        return self._thread.is_alive() and not self._kill_evt.is_set()

    def kill(self) -> None:
        self._kill_evt.set()

    def join(self, timeout: Optional[float] = None) -> None:
        self._thread.join(timeout=timeout)


class ProcessHandle:
    """Real ``spawn`` child process; ``kill()`` is SIGKILL."""

    backend = "process"

    def __init__(self, name: str, worker_cfg) -> None:
        import dataclasses

        from repro.serve.fleet.worker import _process_main
        self.name = name
        ctx = mp.get_context("spawn")
        self._in = ctx.Queue()
        self._out = ctx.Queue()
        self._proc = ctx.Process(
            target=_process_main,
            args=(name, dataclasses.asdict(worker_cfg), self._in, self._out),
            daemon=True, name=f"fleet-{name}")
        self._proc.start()

    @property
    def pid(self) -> Optional[int]:
        return self._proc.pid

    def send(self, msg: Message) -> None:
        if not self._proc.is_alive():
            raise TransportError(f"worker {self.name} process is dead")
        try:
            self._in.put(msg)
        except (ValueError, OSError) as e:  # closed queue / broken pipe
            raise TransportError(str(e)) from e

    def poll(self, timeout: float) -> Optional[Message]:
        try:
            return self._out.get(timeout=timeout)
        except queue_mod.Empty:
            return None
        except (EOFError, OSError, ValueError) as e:
            raise TransportError(str(e)) from e

    def alive(self) -> bool:
        return self._proc.is_alive()

    def kill(self) -> None:
        try:
            self._proc.kill()
        except (ValueError, AttributeError):
            pass  # already reaped
        # a killed worker's inbox may still hold frames its feeder
        # thread can never flush into the dead reader's full pipe; the
        # queue's atexit handler would join that stuck feeder forever
        # and block interpreter shutdown — cancel the join
        for q in (self._in, self._out):
            try:
                q.cancel_join_thread()
            except (OSError, ValueError):
                pass

    def join(self, timeout: Optional[float] = None) -> None:
        self._proc.join(timeout=timeout)


def make_handle(backend: str, name: str, worker_cfg):
    if backend == "thread":
        return ThreadHandle(name, worker_cfg)
    if backend == "process":
        return ProcessHandle(name, worker_cfg)
    raise ValueError(f"unknown fleet backend {backend!r}; "
                     "one of ('thread', 'process')")


__all__ = [
    "Endpoint", "Message", "ProcessHandle", "ThreadHandle", "TransportError",
    "decode_error", "decode_request", "encode_error", "encode_request",
    "lane_key", "make_handle",
]
