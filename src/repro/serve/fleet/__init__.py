"""repro.serve.fleet — fault-tolerant multi-worker serving.

A :class:`ServingFleet` puts N supervised workers (daemon threads or
real ``spawn`` processes — same protocol, see
:mod:`repro.serve.fleet.rpc`) behind the familiar submit/infer/report
surface:

* :mod:`~repro.serve.fleet.router` — lane-sticky placement for
  warm-executor locality plus the request journal that makes failover
  **at-most-once** (a future resolves exactly once no matter how many
  workers raced on the request);
* :mod:`~repro.serve.fleet.supervisor` — worker lifecycle states, the
  atomically-claimed death/restart guard, heartbeat + straggler
  tracking through :mod:`repro.ft.health`;
* :mod:`~repro.serve.fleet.worker` — the loop each worker runs: a
  private foreground :class:`ContinuousBatchEngine`, heartbeats, warm
  pre-compilation, hedged-duplicate cancellation;
* :mod:`~repro.serve.fleet.autoscale` — queue-depth/p99 elastic sizing
  with hysteresis;
* :mod:`~repro.serve.fleet.fleet` — the facade wiring it together,
  including the parent-side chaos sites (``fleet.worker``,
  ``fleet.heartbeat``, ``fleet.rpc``).

Everything observable lands in ``obs.snapshot()`` under ``fleet_*``
counters/gauges; ``ServingFleet.report()`` speaks the canonical
``p50_ms``/``p99_ms``/``waste`` vocabulary.
"""
from repro.serve.fleet.autoscale import AutoscaleConfig, Autoscaler
from repro.serve.fleet.fleet import FleetConfig, ServingFleet
from repro.serve.fleet.router import JournalEntry, Router
from repro.serve.fleet.rpc import (ProcessHandle, ThreadHandle,
                                   TransportError)
from repro.serve.fleet.supervisor import FleetSupervisor, WorkerState
from repro.serve.fleet.worker import FleetWorker, WorkerConfig

__all__ = [
    "AutoscaleConfig", "Autoscaler", "FleetConfig", "FleetSupervisor",
    "FleetWorker", "JournalEntry", "ProcessHandle", "Router",
    "ServingFleet", "ThreadHandle", "TransportError", "WorkerConfig",
    "WorkerState",
]
