"""The fleet worker loop: one engine, one carrier, one heartbeat.

A :class:`FleetWorker` runs inside its handle's thread or process and
hosts a private serving engine — by default a foreground
:class:`~repro.serve.runtime.continuous.ContinuousBatchEngine`
(``background=False``, ``adaptive=False``: the worker steps it inline,
and a fixed bucket grid keeps every worker's dispatch byte-identical so
failover can't change results).  The loop:

* admits ``("req", rid, payload)`` into the engine, steps it, and ships
  each resolved future back as ``("res", rid, ok, value)``;
* emits ``("hb", seq, pending)`` from a side thread every
  ``heartbeat_interval_s`` — the parent's missed-heartbeat detection
  watches these;
* honors ``("hang", seconds)`` by wedging both the loop and the
  heartbeat thread (chaos uses this to simulate a live-but-stuck
  worker: the process is alive, the heartbeats are not);
* pre-compiles lanes on ``("warm", payloads)`` and answers ``("ready",)``
  once hot — a spawned worker joins the rotation already compiled;
* tracks ``("cancel", rid)`` so a hedged request's loser is dropped at
  the worker instead of shipped back as a duplicate.

``WorkerConfig`` is deliberately primitives-only: it must pickle into a
``spawn`` child.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, Optional, Set, Tuple

import numpy as np

from repro.serve.fleet import rpc


@dataclasses.dataclass
class WorkerConfig:
    """Engine + cadence knobs for one fleet worker (picklable)."""

    engine: str = "continuous"    # "continuous" | "batch"
    slots: int = 4                # continuous: slot pool per lane
    policy: str = "auto"
    form: str = "auto"
    max_wait_ms: float = 2.0      # lane age-out (continuous)
    max_batch: int = 8            # batch engine flush size
    max_delay_ms: float = 2.0     # batch engine window
    retry_attempts: int = 3
    heartbeat_interval_s: float = 0.02
    poll_interval_s: float = 0.002
    block_m: int = 16             # SparseMatrix rebuild geometry
    block_n: int = 16
    formats: Tuple[str, ...] = ("ell", "csr")
    seed: int = 0


def _plain(obj):
    """Recursively strip report dicts / numpy scalars to picklable
    builtins (worker reports cross the process boundary)."""
    if isinstance(obj, dict):
        return {k: _plain(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_plain(v) for v in obj]
    if isinstance(obj, np.generic):
        return obj.item()
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    return obj


class FleetWorker:
    """The loop run by a worker thread/process (see module docstring)."""

    def __init__(self, cfg: WorkerConfig, name: str = "worker"):
        self.cfg = cfg
        self.name = name
        self._pending: Dict[int, Any] = {}     # rid -> future
        self._cancelled: Set[int] = set()
        self._stop = False
        self._hang_until: Optional[float] = None  # monotonic deadline
        self._hang_lock = threading.Lock()
        self._engine = None

    # -- engine -------------------------------------------------------------

    def _build_engine(self):
        from repro.resilience.retry import RetryPolicy
        retry = RetryPolicy(max_attempts=self.cfg.retry_attempts,
                            base_ms=0.5, max_ms=5.0)
        if self.cfg.engine == "batch":
            from repro.serve.engine import (BatchServeConfig,
                                            BatchServingEngine)
            return BatchServingEngine(scfg=BatchServeConfig(
                max_batch=self.cfg.max_batch,
                max_delay_ms=self.cfg.max_delay_ms,
                policy=self.cfg.policy, form=self.cfg.form,
                retry=retry, seed=self.cfg.seed))
        from repro.serve.runtime.continuous import (ContinuousBatchEngine,
                                                    ContinuousConfig)
        return ContinuousBatchEngine(cfg=ContinuousConfig(
            slots=self.cfg.slots, policy=self.cfg.policy,
            form=self.cfg.form, max_wait_ms=self.cfg.max_wait_ms,
            adaptive=False, background=False,
            retry=retry, seed=self.cfg.seed))

    def _submit(self, payload) -> Any:
        mat, h, steps = rpc.decode_request(
            payload, formats=self.cfg.formats,
            block=(self.cfg.block_m, self.cfg.block_n))
        if self.cfg.engine == "batch":
            if steps != 1:
                raise ValueError("batch engine serves single-step only")
            return self._engine.submit(mat, h)
        return self._engine.submit(mat, h, steps=steps)

    # -- hang plumbing ------------------------------------------------------

    def _hanging(self) -> bool:
        with self._hang_lock:
            if self._hang_until is None:
                return False
            if time.monotonic() >= self._hang_until:
                self._hang_until = None
                return False
            return True

    def _hang(self, seconds: Optional[float]) -> None:
        with self._hang_lock:
            self._hang_until = time.monotonic() + (
                float(seconds) if seconds else 3600.0)

    # -- heartbeat side thread ---------------------------------------------

    def _hb_loop(self, ep: rpc.Endpoint) -> None:
        seq = 0
        while not self._stop and not ep.killed():
            if not self._hanging():
                seq += 1
                try:
                    ep.send(("hb", seq, len(self._pending)))
                except Exception:  # noqa: BLE001 — carrier died; loop exits
                    return
            time.sleep(self.cfg.heartbeat_interval_s)

    # -- result shipping ----------------------------------------------------

    def _flush(self, ep: rpc.Endpoint) -> None:
        done = [rid for rid, f in self._pending.items() if f.done()]
        for rid in done:
            fut = self._pending.pop(rid)
            if rid in self._cancelled:
                self._cancelled.discard(rid)
                exc = fut.exception()  # consume; loser result is dropped
                del exc
                continue
            exc = fut.exception()
            if exc is None:
                value = np.asarray(fut.result())
                ep.send(("res", rid, True, value))
            else:
                ep.send(("res", rid, False, rpc.encode_error(exc)))

    def _step_engine(self) -> None:
        if self.cfg.engine == "continuous":
            self._engine.step()
        # the batch engine runs its own serve thread; nothing to step

    def _drain_engine(self, ep: rpc.Endpoint, timeout: float = 30.0) -> None:
        t0 = time.monotonic()
        while self._pending and time.monotonic() - t0 < timeout \
                and not ep.killed():
            if self.cfg.engine == "continuous":
                self._engine.step(force=True)
            else:
                time.sleep(self.cfg.poll_interval_s)
            self._flush(ep)

    def _warm(self, payloads) -> None:
        futs = []
        for payload in payloads:
            try:
                futs.append(self._submit(payload))
            except Exception:  # noqa: BLE001 — a bad sample must not
                pass           # keep the worker from coming up
        t0 = time.monotonic()
        while any(not f.done() for f in futs) \
                and time.monotonic() - t0 < 30.0:
            if self.cfg.engine == "continuous":
                self._engine.step(force=True)
            else:
                time.sleep(self.cfg.poll_interval_s)

    # -- main loop ----------------------------------------------------------

    def run(self, ep: rpc.Endpoint) -> None:
        # heartbeats start before the engine exists: building it pays
        # the jax import + first compiles, and a worker must not read
        # as dead while it is warming up
        hb = threading.Thread(target=self._hb_loop, args=(ep,), daemon=True,
                              name=f"fleet-{self.name}-hb")
        hb.start()
        self._engine = self._build_engine()
        ep.send(("ready",))
        try:
            while not self._stop and not ep.killed():
                if self._hanging():
                    time.sleep(self.cfg.poll_interval_s)
                    continue
                msg = ep.recv(timeout=self.cfg.poll_interval_s)
                if msg is not None:
                    self._handle(ep, msg)
                    if self._stop:
                        break
                self._step_engine()
                self._flush(ep)
        finally:
            self._stop = True
            try:
                self._engine.close()
            except Exception:  # noqa: BLE001
                pass

    def _handle(self, ep: rpc.Endpoint, msg: rpc.Message) -> None:
        kind = msg[0]
        if kind == "req":
            _, rid, payload = msg
            try:
                self._pending[rid] = self._submit(payload)
            except Exception as e:  # noqa: BLE001 — decode/admit failure
                ep.send(("res", rid, False, rpc.encode_error(e)))
        elif kind == "cancel":
            rid = msg[1]
            if rid in self._pending:
                self._cancelled.add(rid)
        elif kind == "warm":
            self._warm(msg[1])
            ep.send(("ready",))
        elif kind == "hang":
            self._hang(msg[1])
        elif kind == "report":
            try:
                report = _plain(dict(self._engine.report()))
            except Exception as e:  # noqa: BLE001
                report = {"error": str(e)}
            ep.send(("report_res", msg[1], report))
        elif kind == "drain":
            self._drain_engine(ep)
            ep.send(("drained", msg[1]))
        elif kind == "stop":
            self._drain_engine(ep, timeout=5.0)
            ep.send(("bye",))
            self._stop = True


def _process_main(name: str, cfg_dict: Dict[str, Any], in_q, out_q) -> None:
    """Spawn-child entry point (top-level so it pickles by name)."""
    cfg_dict = dict(cfg_dict)
    cfg_dict["formats"] = tuple(cfg_dict.get("formats", ("ell", "csr")))
    cfg = WorkerConfig(**cfg_dict)
    ep = rpc.Endpoint(in_q, out_q)
    FleetWorker(cfg, name=name).run(ep)


__all__ = ["FleetWorker", "WorkerConfig"]
