"""Request routing and the journal that makes failover at-most-once.

Every request the fleet accepts gets a :class:`JournalEntry` keyed by a
monotonically increasing request id.  The journal is the single source
of truth for a request's life: which worker holds it, whether a hedge
duplicate is out, and — critically — whether its future has already
resolved.  All completion paths funnel through :meth:`Router.complete`,
which flips ``done`` under the fleet lock exactly once; any later
completion for the same rid (a result that was already in the pipe when
its worker was killed, a hedge loser racing its cancel, a failover
re-execution racing a zombie) is counted in
``fleet_duplicate_results_total`` and dropped.  Futures therefore
resolve at most once no matter how many workers end up running the
request.

Placement is deliberately boring: a lane (pow2-rows, feature-dim) is
assigned to a worker round-robin on first sight and stays **sticky**
so repeat traffic hits the worker that already compiled that bucket
(warm-executor locality).  Stickiness yields only when the owner dies
(failover reassigns) or when the owner's in-flight load exceeds
``rebalance_factor`` times the fleet mean (counted in
``fleet_rebalances_total``).  Load-based placement would be faster for
adversarial mixes but timing-dependent — round-robin keeps a seeded
storm byte-reproducible, which the acceptance tests rely on.

When no live worker exists (all dead or restarting) dispatch parks the
rid on the ``unrouted`` queue instead of failing it; the supervisor
re-drives the queue the moment a worker comes up.  Requests only fail
with :class:`~repro.resilience.errors.WorkerLostError` once the restart
budget is truly exhausted.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import time
from concurrent.futures import Future
from typing import Any, Callable, Deque, Dict, List, Optional, Set, Tuple

from repro import obs
from repro.serve.fleet import rpc


@dataclasses.dataclass
class JournalEntry:
    """One accepted request's routing state (owned by the fleet lock)."""

    rid: int
    payload: Dict[str, Any]
    lane: Tuple[int, int]
    future: Future
    t_submit: float
    tag: Any = None
    worker: Optional[str] = None        # primary assignment
    hedge_worker: Optional[str] = None  # duplicate assignment, if hedged
    t_dispatch: float = 0.0
    attempts: int = 0
    done: bool = False
    ok: bool = False


class Router:
    """Lane-sticky placement + the at-most-once journal.

    ``send(worker, msg) -> bool`` and ``live() -> [names]`` are supplied
    by the fleet; ``lock`` is the fleet-wide mutex (shared so journal
    state and worker state flip together).
    """

    def __init__(self, *, send: Callable[[str, rpc.Message], bool],
                 live: Callable[[], List[str]],
                 lock, rebalance_factor: float = 4.0,
                 keep_done: int = 4096):
        self._send = send
        self._live = live
        self._lock = lock
        self.rebalance_factor = float(rebalance_factor)
        self.keep_done = int(keep_done)
        self.journal: Dict[int, JournalEntry] = {}
        self._done_order: Deque[int] = collections.deque()
        self.lane_owner: Dict[Tuple[int, int], str] = {}
        self.lane_sample: Dict[Tuple[int, int], Dict[str, Any]] = {}
        self.lane_hits: collections.Counter = collections.Counter()
        self.inflight: Dict[str, Set[int]] = collections.defaultdict(set)
        self.unrouted: Deque[int] = collections.deque()
        self._rids = itertools.count(1)
        self._rr = 0

    # -- admission ----------------------------------------------------------

    def admit(self, payload: Dict[str, Any], *, tag: Any = None
              ) -> JournalEntry:
        """Journal a new request (does not dispatch it)."""
        lane = rpc.lane_key(payload)
        with self._lock:
            entry = JournalEntry(
                rid=next(self._rids), payload=payload, lane=lane,
                future=Future(), t_submit=time.monotonic(), tag=tag)
            self.journal[entry.rid] = entry
            self.lane_sample[lane] = payload
            self.lane_hits[lane] += 1
        return entry

    # -- placement ----------------------------------------------------------

    def _pick(self, lane: Tuple[int, int],
              exclude: Tuple[str, ...]) -> Optional[str]:
        live = [w for w in self._live() if w not in exclude]
        if not live:
            return None
        owner = self.lane_owner.get(lane)
        if owner in live:
            mean = sum(len(self.inflight[w]) for w in live) / len(live)
            if len(live) > 1 and \
                    len(self.inflight[owner]) > \
                    self.rebalance_factor * max(mean, 1.0):
                new = min(live, key=lambda w: (len(self.inflight[w]), w))
                if new != owner:
                    self.lane_owner[lane] = new
                    obs.counter("fleet_rebalances_total").inc()
                    return new
            return owner
        w = live[self._rr % len(live)]
        self._rr += 1
        self.lane_owner[lane] = w
        return w

    def dispatch(self, entry: JournalEntry,
                 exclude: Tuple[str, ...] = ()) -> bool:
        """Send an entry to a worker; parks it unrouted when none can
        take it.  Returns True when it is on a worker."""
        tried = tuple(exclude)
        while True:
            with self._lock:
                if entry.done:
                    return True
                w = self._pick(entry.lane, tried)
                if w is None:
                    if entry.rid not in self.unrouted:
                        self.unrouted.append(entry.rid)
                    obs.counter("fleet_unrouted_total").inc()
                    return False
                entry.worker = w
                entry.t_dispatch = time.monotonic()
                entry.attempts += 1
                self.inflight[w].add(entry.rid)
            if self._send(w, ("req", entry.rid, entry.payload)):
                return True
            with self._lock:
                self.inflight[w].discard(entry.rid)
                entry.worker = None
                if self.lane_owner.get(entry.lane) == w:
                    del self.lane_owner[entry.lane]
            tried = tried + (w,)

    # -- completion (the at-most-once gate) ---------------------------------

    def complete(self, rid: int, ok: bool, value: Any, src: str
                 ) -> Optional[Tuple[JournalEntry, Optional[str]]]:
        """First completion wins: returns (entry, other-worker-to-cancel)
        and resolves the future; duplicates return None."""
        with self._lock:
            entry = self.journal.get(rid)
            if entry is None or entry.done:
                obs.counter("fleet_duplicate_results_total").inc()
                return None
            entry.done = True
            entry.ok = bool(ok)
            self._done_order.append(rid)
            other = None
            for w in (entry.worker, entry.hedge_worker):
                if w is not None:
                    self.inflight[w].discard(rid)
                    if w != src:
                        other = w
            self._gc_done_locked()
        if ok:
            entry.future.set_result(value)
        else:
            entry.future.set_exception(
                value if isinstance(value, BaseException)
                else rpc.decode_error(value))
        return entry, other

    def fail(self, entry: JournalEntry, exc: BaseException) -> bool:
        """Terminal failure (budget exhausted / close): resolve the
        future with ``exc`` unless something already completed it."""
        got = self.complete(entry.rid, False, exc, src="<fleet>")
        return got is not None

    def _gc_done_locked(self) -> None:
        while len(self._done_order) > self.keep_done:
            rid = self._done_order.popleft()
            self.journal.pop(rid, None)

    # -- failover / hedging -------------------------------------------------

    def orphans_of(self, worker: str) -> List[JournalEntry]:
        """Strip a dead worker's assignments; returns its unfinished
        entries (the caller re-dispatches them) and un-sticks its lanes."""
        with self._lock:
            rids = self.inflight.pop(worker, set())
            out = []
            for rid in rids:
                entry = self.journal.get(rid)
                if entry is None or entry.done:
                    continue
                if entry.worker == worker:
                    entry.worker = None
                if entry.hedge_worker == worker:
                    entry.hedge_worker = None
                if entry.worker is None and entry.hedge_worker is None:
                    out.append(entry)
            for lane, owner in list(self.lane_owner.items()):
                if owner == worker:
                    del self.lane_owner[lane]
            return out

    def hedge_candidate(self, worker: str, older_than_s: float
                        ) -> Optional[JournalEntry]:
        """The worker's oldest un-hedged in-flight entry past the age
        threshold (None if it has nothing hedge-worthy)."""
        now = time.monotonic()
        with self._lock:
            best = None
            for rid in self.inflight.get(worker, ()):
                e = self.journal.get(rid)
                if e is None or e.done or e.hedge_worker is not None \
                        or e.worker != worker:
                    continue
                if now - e.t_dispatch < older_than_s:
                    continue
                if best is None or e.t_dispatch < best.t_dispatch:
                    best = e
            return best

    def hedge(self, entry: JournalEntry) -> bool:
        """Send a duplicate of ``entry`` to a different live worker;
        first result wins (``complete`` cancels the loser)."""
        with self._lock:
            if entry.done or entry.hedge_worker is not None \
                    or entry.worker is None:
                return False
            live = [w for w in self._live() if w != entry.worker]
            if not live:
                return False
            w = min(live, key=lambda n: (len(self.inflight[n]), n))
            entry.hedge_worker = w
            self.inflight[w].add(entry.rid)
        if self._send(w, ("req", entry.rid, entry.payload)):
            obs.counter("fleet_hedges_total").inc()
            return True
        with self._lock:
            self.inflight[w].discard(entry.rid)
            if entry.hedge_worker == w:
                entry.hedge_worker = None
        return False

    # -- queries ------------------------------------------------------------

    def pending(self) -> int:
        with self._lock:
            return sum(1 for e in self.journal.values() if not e.done)

    def pending_entries(self) -> List[JournalEntry]:
        with self._lock:
            return [e for e in self.journal.values() if not e.done]

    def take_unrouted(self) -> List[JournalEntry]:
        """Pop every parked rid (caller re-dispatches)."""
        with self._lock:
            out = []
            while self.unrouted:
                e = self.journal.get(self.unrouted.popleft())
                if e is not None and not e.done:
                    out.append(e)
            return out

    def hot_lanes(self, k: int = 2) -> List[Dict[str, Any]]:
        """Sample payloads of the ``k`` most-hit lanes (warm fodder)."""
        with self._lock:
            lanes = [lane for lane, _ in self.lane_hits.most_common(k)]
            return [self.lane_sample[l] for l in lanes
                    if l in self.lane_sample]


__all__ = ["JournalEntry", "Router"]
