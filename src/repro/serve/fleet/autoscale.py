"""Elastic fleet sizing from backlog depth and tail latency.

Pure decision logic — :meth:`Autoscaler.decide` looks at the current
backlog-per-worker and recent p99 and answers ``"up"``, ``"down"`` or
``None``; the fleet supervisor actuates (spawn a warm worker / drain
and retire one).  Keeping the policy side-effect free makes it unit-
testable with an injected clock, and keeps its hysteresis honest:

* **up** when backlog per live worker exceeds ``up_pending_per_worker``
  (or p99 exceeds ``up_p99_ms`` when set) and the fleet is below
  ``max_workers``;
* **down** when backlog per worker has stayed below
  ``down_pending_per_worker`` for ``idle_grace_s`` and the fleet is
  above ``min_workers`` — the grace window stops one idle tick from
  retiring a worker a bursty trace will want back;
* at most one action per ``cooldown_s`` so the controller cannot
  flap faster than a spawned worker can warm up.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass
class AutoscaleConfig:
    enabled: bool = False
    min_workers: int = 1
    max_workers: int = 4
    up_pending_per_worker: float = 8.0
    up_p99_ms: Optional[float] = None
    down_pending_per_worker: float = 0.5
    idle_grace_s: float = 1.0
    cooldown_s: float = 2.0


class Autoscaler:
    """Hysteresis-guarded scale decisions (no side effects)."""

    def __init__(self, cfg: Optional[AutoscaleConfig] = None):
        self.cfg = cfg if cfg is not None else AutoscaleConfig()
        self._last_action_t: Optional[float] = None
        self._low_since: Optional[float] = None

    def decide(self, now: float, *, pending: int, live_workers: int,
               p99_ms: Optional[float] = None) -> Optional[str]:
        cfg = self.cfg
        if not cfg.enabled or live_workers <= 0:
            return None
        if self._last_action_t is not None \
                and now - self._last_action_t < cfg.cooldown_s:
            return None
        per = pending / live_workers
        hot = per > cfg.up_pending_per_worker or (
            cfg.up_p99_ms is not None and p99_ms is not None
            and p99_ms > cfg.up_p99_ms)
        if hot:
            self._low_since = None
            if live_workers < cfg.max_workers:
                self._last_action_t = now
                return "up"
            return None
        if per < cfg.down_pending_per_worker \
                and live_workers > cfg.min_workers:
            if self._low_since is None:
                self._low_since = now
            elif now - self._low_since >= cfg.idle_grace_s:
                self._last_action_t = now
                self._low_since = None
                return "down"
        else:
            self._low_since = None
        return None


__all__ = ["AutoscaleConfig", "Autoscaler"]
