"""DeltaGraph: a mutable overlay applying edge deltas in place.

Serving workloads over evolving graphs (recommendation, streaming GNNs)
see a trickle of edge inserts/deletes between queries.  Rebuilding the
packed layout per delta is O(nnz) host work *and* — because
``MatrixStats`` ride the jit cache key — a retrace of every consumer.
``DeltaGraph`` absorbs deltas by **patching slots in place**:

* **Slack slots**: the overlay reserves spare zero slots at pack time
  (a slack fraction of extra triplet rows for csr; ``width_slack``
  extra slots per row of every kept SELL slice).  An insert claims a
  free slot and writes the new coordinate/value into it.
* **Tombstones**: a delete zeroes its slot's value.  Every consuming
  path multiplies by the stored value (``spmm_elements``,
  ``sddmm_elements``, the sell reference and kernels mask against
  ``slot_vals``), so a tombstone contributes exactly 0 — no compaction
  needed until repack.
* **Sentinel remap (sell)**: the tile view mirrors each patch — an
  insert maps its tile cell to the claimed slot
  (``tile_slot_map``/``slot_tile_pos``), a delete resets cell and slot
  back to the layout's dead sentinels.  Slot count, tile count and all
  static aux stay bit-identical, so the kernel route stays valid.

Between repacks the served matrix carries **capacity stats**
(:meth:`MatrixStats.with_capacity` — constant regardless of the live
edge count), so consumers under ``jax.jit`` NEVER retrace on a delta.
The price is that the planner keeps pricing the overlay at capacity;
:attr:`exact_stats` (lazily recomputed, ``stats_invalidations``
counter) exposes the live structure, and every **repack** re-stamps
fresh measured stats + a fresh plan memo so the planner re-prices at
exactly the boundaries where a retrace already happens.

A repack runs when slack is exhausted (an insert finds no free slot —
for sell also: target row pruned, or target tile absent) — or in the
background via :meth:`maybe_repack_async` once free slots fall under a
low-water mark: the new packing is built from a snapshot on a worker
thread while the old overlay keeps serving, deltas landing meanwhile
are journaled, and the swap replays the journal onto the new packing.
"""
from __future__ import annotations

import threading
from dataclasses import replace
from typing import Any, Dict, Iterable, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.formats import SellCS
from repro.resilience import chaos
from repro.dispatch.stats import MatrixStats
from repro.sparse.matrix import SparseMatrix

Delta = Tuple[str, int, int, float]  # ("insert"|"delete", row, col, value)


class _CsrOverlay:
    """Element-triplet storage with a global free-slot pool.

    The triplet layout is row-agnostic (any slot can hold any row's
    entry — ``segment_sum`` routes by the stored row id), so slack is
    pooled globally instead of per row: one pool serves whichever rows
    actually churn.
    """

    form = "csr"

    def __init__(self, dense: np.ndarray, slack: float):
        r, c = np.nonzero(dense)
        nnz = len(r)
        cap = nnz + max(int(np.ceil(nnz * slack)), 16)
        self.rows_h = np.zeros(cap, np.int32)
        self.cols_h = np.zeros(cap, np.int32)
        self.vals_h = np.zeros(cap, dense.dtype)
        self.rows_h[:nnz] = r
        self.cols_h[:nnz] = c
        self.vals_h[:nnz] = dense[r, c]
        self.free: List[int] = list(range(cap - 1, nnz - 1, -1))
        self.edge_map: Dict[Tuple[int, int], int] = {
            (int(r[i]), int(c[i])): i for i in range(nnz)}
        self.shape = dense.shape

    @property
    def capacity(self) -> int:
        return len(self.vals_h)

    def free_slots(self) -> int:
        return len(self.free)

    def insert(self, r: int, c: int, v: float) -> bool:
        slot = self.edge_map.get((r, c))
        if slot is not None:
            self.vals_h[slot] = v
            return True
        if not self.free:
            return False
        slot = self.free.pop()
        self.rows_h[slot] = r
        self.cols_h[slot] = c
        self.vals_h[slot] = v
        self.edge_map[(r, c)] = slot
        return True

    def delete(self, r: int, c: int) -> None:
        slot = self.edge_map.pop((r, c))
        # tombstone: value 0 contributes nothing to SpMM/SDDMM/densify;
        # park the coordinate at (0, 0) so the pattern stays tidy
        self.vals_h[slot] = 0
        self.rows_h[slot] = 0
        self.cols_h[slot] = 0
        self.free.append(slot)

    def container(self):
        return (jnp.asarray(self.rows_h), jnp.asarray(self.cols_h),
                jnp.asarray(self.vals_h))

    def live_coords(self):
        live = self.vals_h != 0
        return self.rows_h[live], self.cols_h[live]

    def densify(self) -> np.ndarray:
        out = np.zeros(self.shape, self.vals_h.dtype)
        np.add.at(out, (self.rows_h, self.cols_h), self.vals_h)
        return out


class _SellOverlay:
    """SELL-C-σ storage patched through both synchronized views.

    Slack is **per row**: ``width_slack`` extra slots per row of every
    kept slice (reserved by ``SellCS.from_dense``).  Inserts must land
    in an existing row span *and* an existing tile — a row in a pruned
    (all-zero-width) slice, an exhausted row span, or a cell in a tile
    the packing never materialized all force a repack, because creating
    them would change array extents (and therefore the jit key).
    """

    form = "sell"

    def __init__(self, dense: np.ndarray, width_slack: int, *,
                 c: int, sigma: int, block: Tuple[int, int]):
        self.sell0 = SellCS.from_dense(dense, c=c, sigma=sigma,
                                       block=block,
                                       width_slack=width_slack)
        s = self.sell0
        self.shape = dense.shape
        self.bm, self.bn = s.bm, s.bn
        self.n_slots = s.n_slots
        self.n_tiles = s.n_tiles
        self.slot_cols_h = np.asarray(s.slot_cols).copy()
        self.slot_vals_h = np.asarray(s.slot_vals).copy()
        self.tile_slot_map_h = np.asarray(s.tile_slot_map).copy()
        self.slot_tile_pos_h = np.asarray(s.slot_tile_pos).copy()

        # packed-row spans from the bucket descriptors
        self.slot_start: Dict[int, int] = {}
        self.row_width: Dict[int, int] = {}
        off = 0
        for row_off, n_rows, w in s.buckets:
            for i in range(n_rows):
                self.slot_start[row_off + i] = off + i * w
                self.row_width[row_off + i] = w
            off += n_rows * w
        slot_packed = np.zeros(self.n_slots, np.int64)
        for p, lo in self.slot_start.items():
            slot_packed[lo:lo + self.row_width[p]] = p
        self.slot_packed = slot_packed

        og = np.asarray(s.out_gather)
        self.out_gather_h = og
        n_packed = s.n_packed_rows
        self.packed_to_orig = {int(og[r]): r for r in range(self.shape[0])
                               if og[r] < n_packed}

        # tile index: (compact block-row, block-col) -> tile id, plus
        # compact id per *packed* block-row (recovered from live cells)
        tr = np.asarray(s.tile_rows)
        tc = np.asarray(s.tile_cols)
        self.tiles_index = {(int(tr[t]), int(tc[t])): t
                            for t in range(self.n_tiles)}
        self.compact_of_pbr: Dict[int, int] = {}
        for t in range(self.n_tiles):
            cells = self.tile_slot_map_h[t]
            live = cells[cells < self.n_slots]
            if len(live):
                pbr = int(self.slot_packed[live[0]]) // self.bm
                self.compact_of_pbr[pbr] = int(tr[t])

        # per-packed-row free slots and the live edge map
        self.row_free: Dict[int, List[int]] = {
            p: [] for p in self.slot_start}
        self.edge_map: Dict[Tuple[int, int], int] = {}
        for p, lo in self.slot_start.items():
            r = self.packed_to_orig.get(p)
            for slot in range(lo, lo + self.row_width[p]):
                if r is None or self.slot_vals_h[slot] == 0:
                    if r is not None:
                        self.row_free[p].append(slot)
                else:
                    self.edge_map[(r, int(self.slot_cols_h[slot]))] = slot

    @property
    def capacity(self) -> int:
        return self.n_slots

    def free_slots(self) -> int:
        return sum(len(v) for v in self.row_free.values())

    def insert(self, r: int, c: int, v: float) -> bool:
        slot = self.edge_map.get((r, c))
        if slot is not None:
            self.slot_vals_h[slot] = v
            return True
        p = int(self.out_gather_h[r])
        if p not in self.slot_start:      # row lives in a pruned slice
            return False
        free = self.row_free[p]
        if not free:                      # row span exhausted
            return False
        t = self.tiles_index.get(
            (self.compact_of_pbr.get(p // self.bm, -1), c // self.bn))
        if t is None:                     # tile never materialized
            return False
        slot = free.pop()
        i, j = p % self.bm, c % self.bn
        self.slot_cols_h[slot] = c
        self.slot_vals_h[slot] = v
        self.tile_slot_map_h[t, i, j] = slot
        self.slot_tile_pos_h[slot] = (t * self.bm + i) * self.bn + j
        self.edge_map[(r, c)] = slot
        return True

    def delete(self, r: int, c: int) -> None:
        slot = self.edge_map.pop((r, c))
        self.slot_vals_h[slot] = 0
        pos = int(self.slot_tile_pos_h[slot])
        dead_cell = self.n_tiles * self.bm * self.bn
        if pos < dead_cell:
            t, ij = divmod(pos, self.bm * self.bn)
            self.tile_slot_map_h[t, ij // self.bn, ij % self.bn] \
                = self.n_slots
            self.slot_tile_pos_h[slot] = dead_cell
        self.row_free[int(self.slot_packed[slot])].append(slot)

    def container(self) -> SellCS:
        # static aux (shape/c/sigma/buckets/block/live rows) is reused
        # verbatim — only data leaves change, so the jit key cannot move
        return replace(
            self.sell0,
            slot_cols=jnp.asarray(self.slot_cols_h),
            slot_vals=jnp.asarray(self.slot_vals_h),
            tile_slot_map=jnp.asarray(self.tile_slot_map_h),
            slot_tile_pos=jnp.asarray(self.slot_tile_pos_h))

    def live_coords(self):
        live = np.nonzero(self.slot_vals_h)[0]
        rows = np.fromiter(
            (self.packed_to_orig[int(self.slot_packed[s])] for s in live),
            np.int64, count=len(live))
        return rows, self.slot_cols_h[live].astype(np.int64)

    def densify(self) -> np.ndarray:
        return self.container().to_dense()


class DeltaGraph:
    """Mutable sparse graph serving a retrace-stable ``SparseMatrix``.

    ``form`` picks the overlay layout: ``"csr"`` (element triplets,
    global slack pool — absorbs any churn pattern) or ``"sell"``
    (SELL-C-σ with per-row ``width_slack`` — keeps the tile-pruned
    kernel route live; inserts outside the packed structure repack).
    """

    def __init__(self, matrix, *, form: str = "csr",
                 slack: float = 0.25, width_slack: int = 2,
                 c: int = 16, sigma: int = 0,
                 block: Tuple[int, int] = (8, 8)):
        if form not in ("csr", "sell"):
            raise ValueError(
                f"DeltaGraph form must be 'csr' or 'sell', got {form!r}")
        self.form = form
        self.slack = float(slack)
        self.width_slack = int(width_slack)
        self._sell_cfg = dict(c=c, sigma=sigma, block=block)
        self.repacks = 0
        self.repack_failures = 0
        self.deltas_applied = 0
        self.stats_invalidations = 0
        self._lock = threading.RLock()
        self._bg: Optional[threading.Thread] = None
        self._journal: Optional[List[Delta]] = None
        self._pending_swap = None
        dense = self._to_dense(matrix)
        self._pack(dense)

    @staticmethod
    def _to_dense(matrix) -> np.ndarray:
        if isinstance(matrix, SparseMatrix):
            return np.asarray(matrix.densify())
        return np.asarray(matrix)

    # -- packing ------------------------------------------------------------

    def _make_overlay(self, dense: np.ndarray):
        if self.form == "csr":
            return _CsrOverlay(dense, self.slack)
        return _SellOverlay(dense, self.width_slack, **self._sell_cfg)

    def _pack(self, dense: np.ndarray) -> None:
        """(Re)build the overlay and stamp fresh capacity stats."""
        self._overlay = self._make_overlay(dense)
        r, c = np.nonzero(dense)
        measured = MatrixStats.from_coords(dense.shape, r, c)
        # constant between repacks: consumers key their jit cache on it
        self._cap_stats = measured.with_capacity(self._overlay.capacity)
        self._exact: Optional[MatrixStats] = measured
        self._matrix: Optional[SparseMatrix] = None

    def repack(self) -> None:
        """Rebuild the packing around the live edges (fresh slack, fresh
        measured stats, fresh plan memo — consumers retrace once)."""
        with self._lock:
            self._pack(self._overlay.densify())
            self.repacks += 1
            obs.counter("graph_repacks_total", kind="forced").inc()

    # -- delta application --------------------------------------------------

    def insert(self, r: int, c: int, v: float) -> None:
        """Insert (or update) edge (r, c) with value ``v``."""
        if v == 0:
            raise ValueError(
                "insert with value 0 is a delete (0 marks tombstones)")
        with self._lock:
            if not self._overlay.insert(int(r), int(c), float(v)):
                # repack *around* the new edge: a plain repack may not
                # materialize the row/tile this insert needs (sell packs
                # only non-empty structure), so bake it into the snapshot
                dense = self._overlay.densify()
                dense[int(r), int(c)] = v
                self._pack(dense)
                self.repacks += 1
                obs.counter("graph_repacks_total", kind="slack").inc()
            self._note_delta(("insert", int(r), int(c), float(v)))

    def delete(self, r: int, c: int) -> None:
        """Delete edge (r, c) (KeyError when absent)."""
        with self._lock:
            self._overlay.delete(int(r), int(c))
            self._note_delta(("delete", int(r), int(c), 0.0))

    def apply(self, deltas: Iterable[Delta]) -> None:
        """Apply a batch of ("insert"|"delete", r, c, v) deltas."""
        for op, r, c, v in deltas:
            if op == "insert":
                self.insert(r, c, v)
            elif op == "delete":
                self.delete(r, c)
            else:
                raise ValueError(f"unknown delta op {op!r}")

    def _note_delta(self, d: Delta) -> None:
        self.deltas_applied += 1
        obs.counter("graph_deltas_total", op=d[0]).inc()
        self._matrix = None
        if self._exact is not None:
            self._exact = None               # lazily recomputed
            self.stats_invalidations += 1
        if self._journal is not None:
            self._journal.append(d)

    # -- served views -------------------------------------------------------

    @property
    def matrix(self) -> SparseMatrix:
        """The served matrix.  Carries **capacity stats** — identical
        between repacks, so jitted consumers never retrace on deltas."""
        with self._lock:
            if self._matrix is None:
                self._matrix = SparseMatrix(
                    {self.form: self._overlay.container()},
                    self._overlay.shape, self._cap_stats)
            return self._matrix

    @property
    def exact_stats(self) -> MatrixStats:
        """Live-edge stats (recomputed on demand after deltas).  The
        planner prices :attr:`matrix` from capacity stats; this is the
        true structure — compare the two to decide when a repack (and
        its one-retrace re-pricing) is worth taking early."""
        with self._lock:
            if self._exact is None:
                r, c = self._overlay.live_coords()
                self._exact = MatrixStats.from_coords(
                    self._overlay.shape, r, c)
            return self._exact

    @property
    def live_nnz(self) -> int:
        with self._lock:
            return len(self._overlay.edge_map)

    @property
    def capacity(self) -> int:
        return self._overlay.capacity

    def free_slots(self) -> int:
        with self._lock:
            return self._overlay.free_slots()

    # -- background repack --------------------------------------------------

    def maybe_repack_async(self, low_water: float = 0.1) -> bool:
        """Kick off a background repack when free slots fall under
        ``low_water`` (fraction of capacity).  The rebuild runs from a
        snapshot while this overlay keeps serving; call
        :meth:`poll_repack` (or any delta/next call to this) to swap
        the finished packing in.  Returns True when a rebuild started.
        """
        self.poll_repack()
        with self._lock:
            if self._bg is not None:
                return False
            if self.free_slots() > low_water * max(self.capacity, 1):
                return False
            snapshot = self._overlay.densify()
            self._journal = []

            def build():
                try:
                    chaos.hook("delta.repack")
                    self._pending_swap = self._make_overlay(snapshot)
                except Exception:  # noqa: BLE001 — crash-safe swap: a
                    # failed build publishes nothing; the live overlay
                    # never stopped serving (poll_repack sees swap=None)
                    self.repack_failures += 1
                    obs.counter("graph_repack_failures_total").inc()

            self._bg = threading.Thread(target=build, daemon=True)
            self._bg.start()
            return True

    def poll_repack(self, timeout: Optional[float] = None) -> bool:
        """Swap in a finished background repack (True when swapped)."""
        with self._lock:
            if self._bg is None:
                return False
            self._bg.join(timeout=0.0 if timeout is None else timeout)
            if self._bg.is_alive():
                return False
            self._bg = None
            new = self._pending_swap
            journal, self._journal = self._journal, None
            self._pending_swap = None
            if new is None:
                # the build crashed: nothing was published, the old
                # overlay kept serving throughout — recovery is "do
                # nothing", which is the point of the swap protocol
                obs.counter("resilience_recoveries_total",
                            site="delta.repack").inc()
                return False
            old = self._overlay
            self._overlay = new
            dense = None
            for op, r, c, v in journal:
                ok = (self._overlay.insert(r, c, v) if op == "insert"
                      else (self._overlay.delete(r, c), True)[1])
                if not ok:
                    # replay overflowed the fresh slack: fall back to a
                    # synchronous rebuild from the journaled state
                    dense = old.densify()
                    break
            if dense is not None:
                self._overlay = old
                self._pack(dense)
            else:
                r2, c2 = self._overlay.live_coords()
                measured = MatrixStats.from_coords(
                    self._overlay.shape, r2, c2)
                self._cap_stats = measured.with_capacity(
                    self._overlay.capacity)
                self._exact = measured
                self._matrix = None
            self.repacks += 1
            obs.counter("graph_repacks_total", kind="background").inc()
            return True

    # -- reporting ----------------------------------------------------------

    def report(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "form": self.form,
                "live_nnz": self.live_nnz,
                "capacity": self.capacity,
                "free_slots": self.free_slots(),
                "deltas_applied": self.deltas_applied,
                "repacks": self.repacks,
                "repack_failures": self.repack_failures,
                "stats_invalidations": self.stats_invalidations,
                "background_repack_running": self._bg is not None,
            }
