"""Adaptive serving runtime.

Three cooperating pieces that move serving from static, rebuild-heavy
batching to an online-adaptive runtime (see DESIGN.md, "Adaptive
serving runtime"):

* :class:`AdaptiveBucketLadder` — quantile-learned bucket grid fit from
  observed request shapes, re-fit on traffic drift with hysteresis and
  warm-executor carryover.
* :class:`ContinuousBatchEngine` — admission into a running
  block-diagonal batch: fixed slot pools, per-slot completion, freed
  slots recycled without retracing.
* :class:`DeltaGraph` — mutable CSR/SELL overlay absorbing edge
  insert/delete deltas in place (slack slots, tombstones, sentinel
  remap), with stats invalidation and background repack.
"""
from repro.serve.runtime.continuous import (ContinuousBatchEngine,
                                            ContinuousConfig)
from repro.serve.runtime.delta import DeltaGraph
from repro.serve.runtime.ladder import (AdaptiveBucketLadder,
                                        DEFAULT_LADDER, LadderConfig)

__all__ = [
    "AdaptiveBucketLadder",
    "ContinuousBatchEngine",
    "ContinuousConfig",
    "DEFAULT_LADDER",
    "DeltaGraph",
    "LadderConfig",
]
