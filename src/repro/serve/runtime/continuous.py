"""Continuous batching: admission into a running block-diagonal batch.

The micro-batching engine (``repro.serve.engine.BatchServingEngine``)
holds every request until a flush fires (batch full or deadline), then
composes and executes the whole window at once — arrivals during an
execution wait a full window, and a straggler bucket delays the flush
for everyone.  ``ContinuousBatchEngine`` removes the window: requests
are admitted *into a running batch* the moment a slot is free.

Mechanics (all shapes static — the engine never retraces on occupancy):

* Traffic is partitioned into **lanes** keyed by ``(bucket, d)``.  A
  lane owns a fixed pool of ``slots`` request slots, one cached
  all-zero dummy matrix, and one jitted executor (shared with the
  :class:`repro.batch.BucketedExecutor` LRU under the key
  ``ExecutorKey(bucket, slots, d, form)``).
* Every :meth:`step` composes exactly ``slots`` matrices — occupied
  slots contribute their admission-padded matrix, free slots the cached
  dummy.  The occupancy mask is therefore *data* (zero blocks), never
  *shape*: as requests come and go, the executor sees byte-identical
  static metadata (the lane's precomputed combined canonical stats ride
  through :meth:`BatchedSparseMatrix.from_matrices`'s ``stats=``
  override) and never recompiles.
* Requests complete **per slot**: a finished slot resolves its future
  and is immediately recycled to the lane's wait queue; its neighbors
  keep stepping undisturbed.  Multi-step requests (``steps > 1``, e.g.
  power iteration / multi-hop propagation) feed their padded output
  back in as the next step's features and occupy the slot until done —
  heterogeneous step counts coexist in one lane.

Padding is paid once per request at admission (``pad_to_bucket`` +
feature row padding), not once per flush.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.batch.block_diag import BatchedSparseMatrix
from repro.batch.bucketing import (Bucket, canonical_stats, empty_in_bucket,
                                   pad_to_bucket)
from repro.batch.executor import BucketedExecutor, ExecutorKey
from repro.dispatch.stats import MatrixStats
from repro.serve.runtime.ladder import (AdaptiveBucketLadder, LadderConfig,
                                        DEFAULT_LADDER)
from repro.sparse import paths

Array = Any


@dataclasses.dataclass
class ContinuousConfig:
    """Slot-pool and grid knobs of the continuous engine."""

    slots: int = 8             # slot pool per (bucket, d) lane
    policy: str = "auto"       # dispatch policy inside the executor
    form: str = "auto"         # bucket form: auto | csr | ell
    max_executors: int = 64    # LRU cap on cached jitted executors
    queue_depth: int = 1024    # per-lane wait queue bound
    adaptive: bool = True      # learn the bucket grid from traffic
    ladder: LadderConfig = DEFAULT_LADDER
    background: bool = False   # run a stepping thread (else call step())
    idle_sleep_s: float = 0.5e-3
    # a lane executes when its slot pool is full OR its oldest occupant
    # has waited this long — hot lanes run packed, cold lanes still
    # bound their latency (the continuous analog of max_delay_ms)
    max_wait_ms: float = 5.0


@dataclasses.dataclass
class _SlotReq:
    """One admitted request, padded into its lane's bucket."""

    matrix: Any                # bucket-padded SparseMatrix
    features: Any              # [bucket.cols, d] (padded)
    future: Future
    t_submit: float
    remaining: int             # steps left to run
    rows_logical: int          # rows to trim the final output to
    real_rows: int
    real_nnz: int


class _Lane:
    """Fixed-capacity slot pool serving one (bucket, d) cell."""

    def __init__(self, bucket: Bucket, d: int, form: str, n_slots: int,
                 dtype, queue_depth: int):
        self.bucket = bucket
        self.d = d
        self.form = form
        self.dtype = dtype
        self.key = ExecutorKey(bucket=bucket, batch=n_slots, d=d, form=form)
        self.slots: List[Optional[_SlotReq]] = [None] * n_slots
        self.queue: Deque[_SlotReq] = collections.deque()
        self.queue_depth = queue_depth
        self.dummy = empty_in_bucket(bucket, form=form, dtype=dtype)
        self.zero_h = jnp.zeros((bucket.cols, d), dtype)
        # combined canonical stats of `n_slots` bucket copies — computed
        # once so every step's composition carries byte-identical aux
        cs = canonical_stats(bucket)
        self.stats = MatrixStats(
            shape=(n_slots * bucket.rows, n_slots * bucket.cols),
            nnz=n_slots * cs.nnz,
            stored_elements=n_slots * cs.stored_elements,
            block_m=cs.block_m, block_n=cs.block_n,
            n_block_rows=n_slots * cs.n_block_rows,
            ell_width=cs.ell_width, occupancy=cs.occupancy)
        self.steps = 0
        self.slot_steps = 0        # slots * steps (streamed capacity)
        self.occupied_steps = 0    # occupied slot-steps (useful volume)

    @property
    def occupancy(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    def admit(self, req: _SlotReq) -> bool:
        """Seat the request in a free slot, else queue it (False when
        the wait queue is full — caller backpressures)."""
        for i, s in enumerate(self.slots):
            if s is None:
                self.slots[i] = req
                return True
        if len(self.queue) >= self.queue_depth:
            return False
        self.queue.append(req)
        return True

    def recycle(self) -> None:
        """Seat queued requests into freed slots."""
        for i, s in enumerate(self.slots):
            if s is None and self.queue:
                self.slots[i] = self.queue.popleft()


class ContinuousBatchEngine:
    """Serves (graph, features) traffic by admission into running
    block-diagonal batches (see module docstring).

    ``fn(matrix, h)`` is the per-batch program (default: the planned
    ``matrix @ h``); with ``context`` set it is called
    ``fn(context, matrix, h)`` — the same contract as
    :class:`repro.batch.BucketedExecutor`, whose compile cache this
    engine shares.
    """

    def __init__(self, fn: Optional[Callable] = None, *,
                 context: Any = None,
                 cfg: Optional[ContinuousConfig] = None):
        self.cfg = cfg or ContinuousConfig()
        self.ladder: Optional[AdaptiveBucketLadder] = (
            AdaptiveBucketLadder(self.cfg.ladder)
            if self.cfg.adaptive else None)
        self.executor = BucketedExecutor(
            fn, context=context,
            form=self.cfg.form, policy=self.cfg.policy,
            max_batch=self.cfg.slots,
            max_executors=self.cfg.max_executors,
            ladder=self.ladder)
        self._lanes: Dict[Tuple[Bucket, int], _Lane] = {}
        self._lock = threading.RLock()
        self._latencies_ms: List[float] = []
        self.submitted = 0
        self.completed = 0
        self.failed = 0
        self._stop = threading.Event()
        self._worker: Optional[threading.Thread] = None
        if self.cfg.background:
            self._worker = threading.Thread(
                target=self._step_loop, name="continuous-serve", daemon=True)
            self._worker.start()

    @classmethod
    def for_gcn(cls, params, *, cfg: Optional[ContinuousConfig] = None
                ) -> "ContinuousBatchEngine":
        """Engine running a shared-weight GCN over each running batch."""
        from repro.models.gnn import Graph, gcn_forward

        c = cfg or ContinuousConfig()
        policy = c.policy

        def fwd(p, mat, h):
            g = Graph(adj=mat, n_nodes=mat.shape[0])
            return gcn_forward(p, g, h, policy=policy)

        return cls(fwd, context=params, cfg=c)

    # -- admission ----------------------------------------------------------

    def submit(self, matrix, features, *, steps: int = 1) -> Future:
        """Admit one request; resolves to [n_nodes, d_out] (numpy).

        ``steps > 1`` re-feeds the output as the next step's features
        (requires a square bucket and ``d_out == d``) — the request
        holds its slot until all steps ran.
        """
        if self._stop.is_set():
            raise RuntimeError("engine is closed")
        adj = getattr(matrix, "adj", matrix)
        if adj.stats is None:
            raise ValueError(
                "continuous serving needs matrices with stats "
                "(construct with SparseMatrix.from_dense/from_*)")
        h = jnp.asarray(features)
        if h.ndim != 2 or h.shape[0] != adj.shape[1]:
            raise ValueError(
                f"features {h.shape} do not match matrix {adj.shape}")
        if steps < 1:
            raise ValueError(f"steps must be >= 1, got {steps}")
        fut: Future = Future()
        with self._lock, obs.span("serve.admit", engine="continuous"):
            with obs.span("serve.bucket", engine="continuous"):
                bucket = self.executor.bucket_of(adj.stats)
            d = int(h.shape[1])
            if steps > 1 and bucket.rows != bucket.cols:
                raise ValueError(
                    f"steps={steps} needs a square bucket to re-feed the "
                    f"output; got {bucket.rows}x{bucket.cols}")
            lane = self._lanes.get((bucket, d))
            if lane is None:
                carried = [f for f in ("ell", "csr") if adj.has_form(f)]
                form, _ = self.executor.choose_form(bucket, d, carried)
                lane = _Lane(bucket, d, form, self.cfg.slots, h.dtype,
                             self.cfg.queue_depth)
                self._lanes[(bucket, d)] = lane
            mat = adj if adj.has_form(lane.form) else adj.to(lane.form)
            req = _SlotReq(
                matrix=pad_to_bucket(mat, bucket, form=lane.form),
                features=paths.pad_rows(h.astype(lane.dtype), bucket.cols),
                future=fut, t_submit=time.perf_counter(),
                remaining=steps, rows_logical=adj.shape[0],
                real_rows=adj.shape[0], real_nnz=adj.stats.nnz)
            if not lane.admit(req):
                raise RuntimeError(
                    f"lane {bucket.label}/d{d} wait queue is full "
                    f"({lane.queue_depth})")
            self.submitted += 1
        return fut

    def infer(self, matrix, features, *, steps: int = 1) -> np.ndarray:
        """Synchronous convenience: submit, step to completion, return."""
        fut = self.submit(matrix, features, steps=steps)
        if self._worker is None:
            while not fut.done():
                # a step may complete nothing yet still make progress
                # (multi-step requests hold their slot) — stall only
                # when no lane has work at all
                if self.step(force=True) == 0 and not fut.done():
                    with self._lock:
                        stalled = all(l.occupancy == 0
                                      for l in self._lanes.values())
                    if stalled:
                        raise RuntimeError(
                            "request did not complete but no lane has work")
        return fut.result()

    # -- stepping -----------------------------------------------------------

    def step(self, *, force: bool = False) -> int:
        """Run one execution over every *ready* lane (slot pool full,
        or oldest occupant past ``max_wait_ms`` — ``force`` runs any
        lane with occupants); resolve finished slots and recycle them.
        Returns requests completed."""
        now = time.perf_counter()
        wait_s = self.cfg.max_wait_ms / 1e3
        with self._lock:
            lanes = []
            for lane in self._lanes.values():
                occupants = [s for s in lane.slots if s is not None]
                if not occupants:
                    continue
                if (force or len(occupants) == len(lane.slots)
                        or now - min(s.t_submit for s in occupants)
                        >= wait_s):
                    lanes.append(lane)
        done = 0
        for lane in lanes:
            done += self._step_lane(lane)
        return done

    def _step_lane(self, lane: _Lane) -> int:
        with self._lock:
            occupants = [(i, s) for i, s in enumerate(lane.slots)
                         if s is not None]
            if not occupants:
                return 0
            mats = [s.matrix if s is not None else lane.dummy
                    for s in lane.slots]
            feats = [s.features if s is not None else lane.zero_h
                     for s in lane.slots]
        lane_label = self.executor.lane_label(lane.key)
        with obs.span("serve.lane_step", lane=lane_label,
                      occupied=len(occupants)):
            with obs.span("serve.compose", lane=lane_label):
                B = BatchedSparseMatrix.from_matrices(
                    mats, formats=(lane.form,), stats=lane.stats)
                h = jnp.concatenate(feats, axis=0)
            exe = self.executor.executor_for(lane.key)
            args = (B.matrix, h) if self.executor.context is None \
                else (self.executor.context, B.matrix, h)
            try:
                with obs.span("serve.execute", lane=lane_label):
                    t0 = time.perf_counter()
                    y = exe(*args)
                    jax.block_until_ready(y)
                    exec_ms = (time.perf_counter() - t0) * 1e3
            except Exception as exc:  # noqa: BLE001 — fail the lane step
                return self._fail_lane(lane, occupants, exc)
            obs.SENTRY.record_call(lane_label)
            plan = self.executor.bucket_plan(lane.bucket, lane.d)
            obs.AUDIT.record_raw(
                op="spmm", path=lane.form, measured_ms=exec_ms,
                bucket=lane.bucket.label,
                costs=plan.costs if plan is not None else None,
                policy=plan.policy if plan is not None
                else self.cfg.policy)
        t_done = time.perf_counter()
        bucket = lane.bucket
        with self._lock:
            self.executor.calls += 1
            lane.steps += 1
            lane.slot_steps += len(lane.slots)
            lane.occupied_steps += len(occupants)
            self.executor.waste.add(
                real_rows=sum(s.real_rows for _, s in occupants),
                padded_rows=len(lane.slots) * bucket.rows,
                real_nnz=sum(s.real_nnz for _, s in occupants),
                padded_nnz=len(lane.slots) * bucket.nnz,
                bucket=bucket)
            done = 0
            for i, s in occupants:
                lo = i * bucket.rows
                block = y[lo:lo + bucket.rows]
                s.remaining -= 1
                if s.remaining <= 0:
                    self.completed += 1
                    self.executor.requests += 1
                    done += 1
                    lane.slots[i] = None
                    lat_ms = (t_done - s.t_submit) * 1e3
                    self._latencies_ms.append(lat_ms)
                    obs.histogram("serve_latency_ms",
                                  engine="continuous").observe(lat_ms)
                    if not s.future.cancelled():
                        s.future.set_result(
                            np.asarray(block[:s.rows_logical]))
                    continue
                if block.shape != s.features.shape:
                    self.completed += 1
                    self.failed += 1
                    done += 1
                    lane.slots[i] = None
                    if not s.future.cancelled():
                        s.future.set_exception(ValueError(
                            f"multi-step request: step output {block.shape}"
                            f" cannot re-feed features {s.features.shape}"
                            " (d_out must equal d)"))
                    continue
                s.features = block
            lane.recycle()
        return done

    def _fail_lane(self, lane: _Lane, occupants, exc: Exception) -> int:
        with self._lock:
            for i, s in occupants:
                self.completed += 1
                self.failed += 1
                lane.slots[i] = None
                if not s.future.cancelled():
                    s.future.set_exception(exc)
            lane.recycle()
        return len(occupants)

    def _step_loop(self) -> None:
        while not self._stop.is_set():
            if self.step() == 0:
                # nothing ready (idle, or occupants still inside their
                # batching window) — back off briefly
                time.sleep(self.cfg.idle_sleep_s)

    # -- lifecycle ----------------------------------------------------------

    def pending(self) -> int:
        with self._lock:
            return self.submitted - self.completed

    def drain(self, timeout: float = 60.0) -> None:
        """Step (or wait on the background thread) until every admitted
        request has resolved."""
        t0 = time.perf_counter()
        while self.pending() > 0:
            if time.perf_counter() - t0 > timeout:
                raise TimeoutError(
                    f"drain: {self.pending()} requests still pending "
                    f"after {timeout}s")
            if self._worker is None:
                self.step(force=True)
            else:
                time.sleep(0.002)

    def close(self) -> None:
        """Drain in-flight work, then stop.  Every future submitted
        before close resolves — with its result when the drain
        succeeds, with an error otherwise; none is left hanging."""
        try:
            self.drain()
        except Exception:  # noqa: BLE001 — still fail the leftovers below
            pass
        self._stop.set()
        if self._worker is not None:
            self._worker.join(timeout=5.0)
        with self._lock:
            for lane in self._lanes.values():
                leftovers = ([s for s in lane.slots if s is not None]
                             + list(lane.queue))
                lane.slots = [None] * len(lane.slots)
                lane.queue.clear()
                for s in leftovers:
                    self.completed += 1
                    self.failed += 1
                    if not s.future.cancelled():
                        s.future.set_exception(
                            RuntimeError("engine closed"))

    def __enter__(self) -> "ContinuousBatchEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def reset_metrics(self) -> None:
        """Zero traffic counters (keep compiled executors and lanes)."""
        if self.pending():
            raise RuntimeError("reset_metrics with requests in flight; "
                               "drain() first")
        with self._lock:
            self._latencies_ms.clear()
            self.submitted = self.completed = self.failed = 0
            for lane in self._lanes.values():
                lane.steps = lane.slot_steps = lane.occupied_steps = 0
            self.executor.waste = type(self.executor.waste)()
            self.executor.calls = self.executor.requests = 0

    # -- reporting ----------------------------------------------------------

    def report(self) -> Dict[str, Any]:
        """Canonical keys (see DESIGN.md "Observability"); the old
        ``latency_ms_p50``/``latency_ms_p99`` spellings resolve via
        deprecation aliases."""
        with self._lock:
            lat = np.asarray(self._latencies_ms, np.float64)
            lanes = {}
            for (bucket, d), lane in self._lanes.items():
                lanes[f"{bucket.label}/d{d}"] = {
                    "form": lane.form,
                    "slots": len(lane.slots),
                    "steps": lane.steps,
                    "occupancy": (lane.occupied_steps
                                  / max(lane.slot_steps, 1)),
                    "queued": len(lane.queue),
                }
            return obs.renamed_keys({
                "submitted": self.submitted,
                "completed": self.completed,
                "failed": self.failed,
                "pending": self.submitted - self.completed,
                "p50_ms": (float(np.percentile(lat, 50))
                           if len(lat) else 0.0),
                "p99_ms": (float(np.percentile(lat, 99))
                           if len(lat) else 0.0),
                "lanes": lanes,
                "executor": self.executor.report(),
            }, {"latency_ms_p50": "p50_ms", "latency_ms_p99": "p99_ms"})
