"""Continuous batching: admission into a running block-diagonal batch.

The micro-batching engine (``repro.serve.engine.BatchServingEngine``)
holds every request until a flush fires (batch full or deadline), then
composes and executes the whole window at once — arrivals during an
execution wait a full window, and a straggler bucket delays the flush
for everyone.  ``ContinuousBatchEngine`` removes the window: requests
are admitted *into a running batch* the moment a slot is free.

Mechanics (all shapes static — the engine never retraces on occupancy):

* Traffic is partitioned into **lanes** keyed by ``(bucket, d)``.  A
  lane owns a fixed pool of ``slots`` request slots, one cached
  all-zero dummy matrix, and one jitted executor (shared with the
  :class:`repro.batch.BucketedExecutor` LRU under the key
  ``ExecutorKey(bucket, slots, d, form)``).
* Every :meth:`step` composes exactly ``slots`` matrices — occupied
  slots contribute their admission-padded matrix, free slots the cached
  dummy.  The occupancy mask is therefore *data* (zero blocks), never
  *shape*: as requests come and go, the executor sees byte-identical
  static metadata (the lane's precomputed combined canonical stats ride
  through :meth:`BatchedSparseMatrix.from_matrices`'s ``stats=``
  override) and never recompiles.
* Requests complete **per slot**: a finished slot resolves its future
  and is immediately recycled to the lane's wait queue; its neighbors
  keep stepping undisturbed.  Multi-step requests (``steps > 1``, e.g.
  power iteration / multi-hop propagation) feed their padded output
  back in as the next step's features and occupy the slot until done —
  heterogeneous step counts coexist in one lane.

Padding is paid once per request at admission (``pad_to_bucket`` +
feature row padding), not once per flush.

Resilience (see DESIGN.md "Resilience"): a failed lane step no longer
collaterally fails every co-batched occupant.  The engine retries the
step (backoff + jitter, bounded by a per-request allowance and an
engine-wide token-bucket budget), then **bisects** the occupants to
isolate the culprit — poison requests are quarantined with
:class:`PoisonRequestError` while innocents complete from the probe
executions.  NaN/Inf output blocks are quarantined instead of returned.
An executor form that keeps failing is *degraded* (the lane rebuilds on
the surviving form), an over-full wait queue sheds the lowest-priority
/ nearest-deadline request with :class:`RequestShedError`, and a dead
background worker restarts under a bounded supervisor.  Every recovery
action moves an ``obs`` counter.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import Future
from concurrent.futures import TimeoutError as _FutTimeout
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.batch.block_diag import BatchedSparseMatrix
from repro.batch.bucketing import (Bucket, canonical_stats, empty_in_bucket,
                                   pad_to_bucket)
from repro.batch.executor import BucketedExecutor, ExecutorKey
from repro.dispatch.stats import MatrixStats
from repro.resilience import chaos
from repro.resilience.errors import (FATAL, POISON, TRANSIENT,
                                     DeadlineExceededError,
                                     EngineClosedError, NaNOutputError,
                                     RequestShedError,
                                     TransientExecutorError, classify)
from repro.resilience.retry import RetryBudget, RetryPolicy
from repro.resilience.supervisor import WorkerSupervisor
from repro.serve.runtime.ladder import (AdaptiveBucketLadder, LadderConfig,
                                        DEFAULT_LADDER)
from repro.sparse import paths

Array = Any


@dataclasses.dataclass
class ContinuousConfig:
    """Slot-pool, grid, and resilience knobs of the continuous engine."""

    slots: int = 8             # slot pool per (bucket, d) lane
    policy: str = "auto"       # dispatch policy inside the executor
    form: str = "auto"         # bucket form: auto | csr | ell
    max_executors: int = 64    # LRU cap on cached jitted executors
    queue_depth: int = 1024    # per-lane wait queue bound
    adaptive: bool = True      # learn the bucket grid from traffic
    ladder: LadderConfig = DEFAULT_LADDER
    background: bool = False   # run a stepping thread (else call step())
    idle_sleep_s: float = 0.5e-3
    # a lane executes when its slot pool is full OR its oldest occupant
    # has waited this long — hot lanes run packed, cold lanes still
    # bound their latency (the continuous analog of max_delay_ms)
    max_wait_ms: float = 5.0
    # -- resilience ---------------------------------------------------------
    retry: RetryPolicy = RetryPolicy()  # per-request backoff + allowance
    retry_budget: int = 64              # engine-wide retry tokens
    retry_refill_per_s: float = 8.0
    guard_nonfinite: bool = True        # quarantine NaN/Inf output blocks
    default_deadline_ms: Optional[float] = None  # per-request deadline
    default_timeout_s: Optional[float] = 60.0    # infer() overall deadline
    max_worker_restarts: int = 3
    seed: int = 0                       # backoff-jitter rng


@dataclasses.dataclass
class _SlotReq:
    """One admitted request, padded into its lane's bucket."""

    matrix: Any                # bucket-padded SparseMatrix
    features: Any              # [bucket.cols, d] (padded)
    future: Future
    t_submit: float
    remaining: int             # steps left to run
    rows_logical: int          # rows to trim the final output to
    real_rows: int
    real_nnz: int
    source: Any = None         # unpadded adjacency (lane rebuilds re-pad)
    source_h: Any = None       # unpadded features
    steps_total: int = 1
    attempts: int = 0          # transient retries consumed
    priority: int = 0          # higher = shed later
    deadline: Optional[float] = None  # absolute perf_counter deadline
    tag: Any = None            # chaos/match + caller bookkeeping label


class _Lane:
    """Fixed-capacity slot pool serving one (bucket, d) cell."""

    def __init__(self, bucket: Bucket, d: int, form: str, n_slots: int,
                 dtype, queue_depth: int):
        self.bucket = bucket
        self.d = d
        self.form = form
        self.dtype = dtype
        self.key = ExecutorKey(bucket=bucket, batch=n_slots, d=d, form=form)
        self.slots: List[Optional[_SlotReq]] = [None] * n_slots
        self.queue: Deque[_SlotReq] = collections.deque()
        self.queue_depth = queue_depth
        self.dummy = empty_in_bucket(bucket, form=form, dtype=dtype)
        self.zero_h = jnp.zeros((bucket.cols, d), dtype)
        # combined canonical stats of `n_slots` bucket copies — computed
        # once so every step's composition carries byte-identical aux
        cs = canonical_stats(bucket)
        self.stats = MatrixStats(
            shape=(n_slots * bucket.rows, n_slots * bucket.cols),
            nnz=n_slots * cs.nnz,
            stored_elements=n_slots * cs.stored_elements,
            block_m=cs.block_m, block_n=cs.block_n,
            n_block_rows=n_slots * cs.n_block_rows,
            ell_width=cs.ell_width, occupancy=cs.occupancy)
        self.steps = 0
        self.slot_steps = 0        # slots * steps (streamed capacity)
        self.occupied_steps = 0    # occupied slot-steps (useful volume)

    @property
    def occupancy(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    def admit(self, req: _SlotReq) -> bool:
        """Seat the request in a free slot, else queue it (False when
        the wait queue is full — caller sheds)."""
        for i, s in enumerate(self.slots):
            if s is None:
                self.slots[i] = req
                return True
        if len(self.queue) >= self.queue_depth:
            return False
        self.queue.append(req)
        return True

    def recycle(self) -> None:
        """Seat queued requests into freed slots."""
        for i, s in enumerate(self.slots):
            if s is None and self.queue:
                self.slots[i] = self.queue.popleft()


class ContinuousBatchEngine:
    """Serves (graph, features) traffic by admission into running
    block-diagonal batches (see module docstring).

    ``fn(matrix, h)`` is the per-batch program (default: the planned
    ``matrix @ h``); with ``context`` set it is called
    ``fn(context, matrix, h)`` — the same contract as
    :class:`repro.batch.BucketedExecutor`, whose compile cache this
    engine shares.
    """

    def __init__(self, fn: Optional[Callable] = None, *,
                 context: Any = None,
                 cfg: Optional[ContinuousConfig] = None):
        self.cfg = cfg or ContinuousConfig()
        self.ladder: Optional[AdaptiveBucketLadder] = (
            AdaptiveBucketLadder(self.cfg.ladder)
            if self.cfg.adaptive else None)
        self.executor = BucketedExecutor(
            fn, context=context,
            form=self.cfg.form, policy=self.cfg.policy,
            max_batch=self.cfg.slots,
            max_executors=self.cfg.max_executors,
            ladder=self.ladder)
        self._lanes: Dict[Tuple[Bucket, int], _Lane] = {}
        self._lock = threading.RLock()
        self._latencies_ms: List[float] = []
        self._rng = np.random.default_rng(self.cfg.seed)
        self._budget = RetryBudget(self.cfg.retry_budget,
                                   self.cfg.retry_refill_per_s)
        self.submitted = 0
        self.completed = 0
        self.failed = 0
        self.quarantined = 0
        self.shed = 0
        self._stop = threading.Event()
        self._close_once = threading.Lock()
        self._closed = False
        self._sup: Optional[WorkerSupervisor] = None
        if self.cfg.background:
            self._sup = WorkerSupervisor(
                "continuous-serve", self._step_loop,
                max_restarts=self.cfg.max_worker_restarts)
            self._sup.start()

    @classmethod
    def for_gcn(cls, params, *, cfg: Optional[ContinuousConfig] = None
                ) -> "ContinuousBatchEngine":
        """Engine running a shared-weight GCN over each running batch."""
        from repro.models.gnn import Graph, gcn_forward

        c = cfg or ContinuousConfig()
        policy = c.policy

        def fwd(p, mat, h):
            g = Graph(adj=mat, n_nodes=mat.shape[0])
            return gcn_forward(p, g, h, policy=policy)

        return cls(fwd, context=params, cfg=c)

    # -- admission ----------------------------------------------------------

    def submit(self, matrix, features, *, steps: int = 1,
               priority: int = 0, deadline_ms: Optional[float] = None,
               tag: Any = None) -> Future:
        """Admit one request; resolves to [n_nodes, d_out] (numpy).

        ``steps > 1`` re-feeds the output as the next step's features
        (requires a square bucket and ``d_out == d``) — the request
        holds its slot until all steps ran.  ``priority`` orders load
        shedding (lower sheds first); ``deadline_ms`` (default
        ``cfg.default_deadline_ms``) bounds total time in the system —
        an expired queued request fails with
        :class:`DeadlineExceededError`.  When the wait queue is over
        capacity the least valuable request is shed with
        :class:`RequestShedError` (possibly this one: the returned
        future then already holds the error).
        """
        if self._stop.is_set():
            raise EngineClosedError("engine is closed")
        if self._sup is not None:
            self._sup.ensure()
        adj = getattr(matrix, "adj", matrix)
        if adj.stats is None:
            raise ValueError(
                "continuous serving needs matrices with stats "
                "(construct with SparseMatrix.from_dense/from_*)")
        h = jnp.asarray(features)
        if h.ndim != 2 or h.shape[0] != adj.shape[1]:
            raise ValueError(
                f"features {h.shape} do not match matrix {adj.shape}")
        if steps < 1:
            raise ValueError(f"steps must be >= 1, got {steps}")
        ddl_ms = (deadline_ms if deadline_ms is not None
                  else self.cfg.default_deadline_ms)
        fut: Future = Future()
        with self._lock, obs.span("serve.admit", engine="continuous"):
            lane = self._lane_for(adj, int(h.shape[1]), h.dtype)
            if steps > 1 and lane.bucket.rows != lane.bucket.cols:
                raise ValueError(
                    f"steps={steps} needs a square bucket to re-feed the "
                    f"output; got {lane.bucket.rows}x{lane.bucket.cols}")
            t_submit = time.perf_counter()
            req = _SlotReq(
                matrix=pad_to_bucket(
                    adj if adj.has_form(lane.form) else adj.to(lane.form),
                    lane.bucket, form=lane.form),
                features=paths.pad_rows(h.astype(lane.dtype),
                                        lane.bucket.cols),
                future=fut, t_submit=t_submit,
                remaining=steps, rows_logical=adj.shape[0],
                real_rows=adj.shape[0], real_nnz=adj.stats.nnz,
                source=adj, source_h=h, steps_total=steps,
                priority=priority, tag=tag,
                deadline=(t_submit + ddl_ms / 1e3)
                if ddl_ms is not None else None)
            self.submitted += 1
            if not lane.admit(req):
                self._shed_for(lane, req)
        if self._stop.is_set():
            # close() may have swept the lanes between our top-of-submit
            # check and the admit above; sweep again so this request
            # cannot strand in a lane nothing will ever step
            self._fail_leftovers()
        return fut

    def _lane_for(self, adj, d: int, dtype) -> _Lane:
        """The (bucket, d) lane serving this request (lock held)."""
        with obs.span("serve.bucket", engine="continuous"):
            bucket = self.executor.bucket_of(adj.stats)
        lane = self._lanes.get((bucket, d))
        if lane is None:
            carried = [f for f in ("ell", "csr") if adj.has_form(f)]
            form, _ = self.executor.choose_form(bucket, d, carried)
            lane = _Lane(bucket, d, form, self.cfg.slots, dtype,
                         self.cfg.queue_depth)
            self._lanes[(bucket, d)] = lane
        return lane

    def _shed_for(self, lane: _Lane, incoming: _SlotReq) -> None:
        """Wait queue over capacity: shed the least valuable request —
        lowest priority first, nearest deadline breaking ties (lock
        held)."""
        def shed_key(s: _SlotReq):
            return (s.priority,
                    s.deadline if s.deadline is not None else float("inf"))

        victim = min([*lane.queue, incoming], key=shed_key)
        if victim is not incoming:
            lane.queue.remove(victim)
            lane.admit(incoming)
        self.shed += 1
        obs.counter("resilience_shed_total", reason="queue_full").inc()
        self._finish_error(victim, RequestShedError(
            f"lane {lane.bucket.label}/d{lane.d} over capacity "
            f"({lane.queue_depth} queued): request shed "
            f"(priority={victim.priority})"))

    def infer(self, matrix, features, *, steps: int = 1,
              timeout: Optional[float] = None, **submit_kw) -> np.ndarray:
        """Synchronous convenience: submit, step to completion, return.

        ``timeout`` (default ``cfg.default_timeout_s``) bounds the wait;
        expiry raises :class:`DeadlineExceededError` (a
        :class:`TimeoutError`) instead of blocking forever.
        """
        t = self.cfg.default_timeout_s if timeout is None else timeout
        fut = self.submit(matrix, features, steps=steps, **submit_kw)
        if self._sup is not None:
            try:
                return fut.result(t)
            except _FutTimeout as exc:
                if isinstance(exc, DeadlineExceededError):
                    raise
                raise DeadlineExceededError(
                    f"infer: no result within {t}s") from None
        t_deadline = None if t is None else time.perf_counter() + t
        while not fut.done():
            if t_deadline is not None and time.perf_counter() > t_deadline:
                raise DeadlineExceededError(f"infer: no result within {t}s")
            # a step may complete nothing yet still make progress
            # (multi-step requests hold their slot) — stall only
            # when no lane has work at all
            if self.step(force=True) == 0 and not fut.done():
                with self._lock:
                    stalled = all(l.occupancy == 0
                                  for l in self._lanes.values())
                if stalled:
                    raise RuntimeError(
                        "request did not complete but no lane has work")
        return fut.result()

    # -- stepping -----------------------------------------------------------

    def step(self, *, force: bool = False) -> int:
        """Run one execution over every *ready* lane (slot pool full,
        or oldest occupant past ``max_wait_ms`` — ``force`` runs any
        lane with occupants); resolve finished slots and recycle them.
        Expired queued requests fail with DeadlineExceededError.
        Returns requests completed."""
        now = time.perf_counter()
        wait_s = self.cfg.max_wait_ms / 1e3
        expired: List[_SlotReq] = []
        with self._lock:
            lanes = []
            for lane in self._lanes.values():
                if lane.queue and any(s.deadline is not None
                                      and now > s.deadline
                                      for s in lane.queue):
                    keep: Deque[_SlotReq] = collections.deque()
                    for s in lane.queue:
                        if s.deadline is not None and now > s.deadline:
                            expired.append(s)
                        else:
                            keep.append(s)
                    lane.queue = keep
                occupants = [s for s in lane.slots if s is not None]
                if not occupants:
                    continue
                if (force or len(occupants) == len(lane.slots)
                        or now - min(s.t_submit for s in occupants)
                        >= wait_s):
                    lanes.append(lane)
        for s in expired:
            obs.counter("resilience_shed_total", reason="deadline").inc()
            self.shed += 1
            self._finish_error(s, DeadlineExceededError(
                "request deadline expired while queued"))
        done = len(expired)
        for lane in lanes:
            done += self._step_lane(lane)
        return done

    def _step_lane(self, lane: _Lane) -> int:
        with self._lock:
            occupants = [(i, s) for i, s in enumerate(lane.slots)
                         if s is not None]
        if not occupants:
            return 0
        y, exc = self._try_execute(lane, occupants)
        if exc is None:
            done = self._complete_slots(lane, y, occupants)
        else:
            done = self._recover(lane, occupants, exc)
        with self._lock:
            lane.recycle()
        return done

    def _try_execute(self, lane: _Lane, subset) -> Tuple[Any, Any]:
        """Compose + execute the given occupant subset (free and
        excluded slots ride as dummies).  Returns (y, None) on success,
        (None, exc) on failure — never raises."""
        with self._lock:
            mats = [lane.dummy] * len(lane.slots)
            feats: List[Any] = [lane.zero_h] * len(lane.slots)
            for i, s in subset:
                mats[i] = s.matrix
                feats[i] = s.features
        lane_label = self.executor.lane_label(lane.key)
        tags = [s.tag for _, s in subset if s.tag is not None]
        try:
            with obs.span("serve.lane_step", lane=lane_label,
                          occupied=len(subset)):
                with obs.span("serve.compose", lane=lane_label):
                    B = BatchedSparseMatrix.from_matrices(
                        mats, formats=(lane.form,), stats=lane.stats)
                    h = jnp.concatenate(feats, axis=0)
                exe = self.executor.executor_for(lane.key)
                args = (B.matrix, h) if self.executor.context is None \
                    else (self.executor.context, B.matrix, h)
                with obs.span("serve.execute", lane=lane_label):
                    chaos.hook("continuous.execute", lane=lane_label,
                               tags=tags, form=lane.form)
                    t0 = time.perf_counter()
                    y = exe(*args)
                    jax.block_until_ready(y)
                    exec_ms = (time.perf_counter() - t0) * 1e3
                y = chaos.corrupt("continuous.output", y,
                                  lane=lane_label, tags=tags)
        except Exception as exc:  # noqa: BLE001 — classified by caller
            return None, exc
        self.executor.note_success(lane.bucket, lane.d, lane.form)
        obs.SENTRY.record_call(lane_label)
        plan = self.executor.bucket_plan(lane.bucket, lane.d)
        obs.AUDIT.record_raw(
            op="spmm", path=lane.form, measured_ms=exec_ms,
            bucket=lane.bucket.label,
            costs=plan.costs if plan is not None else None,
            policy=plan.policy if plan is not None
            else self.cfg.policy)
        with self._lock:
            self.executor.calls += 1
            lane.steps += 1
            lane.slot_steps += len(lane.slots)
            lane.occupied_steps += len(subset)
            self.executor.waste.add(
                real_rows=sum(s.real_rows for _, s in subset),
                padded_rows=len(lane.slots) * lane.bucket.rows,
                real_nnz=sum(s.real_nnz for _, s in subset),
                padded_nnz=len(lane.slots) * lane.bucket.nnz,
                bucket=lane.bucket)
        return y, None

    def _complete_slots(self, lane: _Lane, y, subset) -> int:
        """Resolve finished subset slots from the output ``y``;
        multi-step members re-feed.  NaN/Inf blocks quarantine."""
        t_done = time.perf_counter()
        bucket = lane.bucket
        done = 0
        with self._lock:
            for i, s in subset:
                if lane.slots[i] is not s:
                    continue  # already resolved by an earlier probe
                lo = i * bucket.rows
                block = y[lo:lo + bucket.rows]
                if self.cfg.guard_nonfinite and \
                        not bool(jnp.isfinite(block).all()):
                    lane.slots[i] = None
                    done += self._quarantine(s, NaNOutputError(
                        "non-finite output block quarantined "
                        f"(request rows={s.rows_logical})"), kind="nan")
                    continue
                s.remaining -= 1
                if s.remaining <= 0:
                    done += 1
                    lane.slots[i] = None
                    self.executor.requests += 1
                    lat_ms = (t_done - s.t_submit) * 1e3
                    self._latencies_ms.append(lat_ms)
                    obs.histogram("serve_latency_ms",
                                  engine="continuous").observe(lat_ms)
                    self.completed += 1
                    if not s.future.done() and not s.future.cancelled():
                        s.future.set_result(
                            np.asarray(block[:s.rows_logical]))
                    continue
                if block.shape != s.features.shape:
                    done += 1
                    lane.slots[i] = None
                    self.completed += 1
                    self.failed += 1
                    if not s.future.done() and not s.future.cancelled():
                        s.future.set_exception(ValueError(
                            f"multi-step request: step output {block.shape}"
                            f" cannot re-feed features {s.features.shape}"
                            " (d_out must equal d)"))
                    continue
                s.features = block
        return done

    # -- recovery -----------------------------------------------------------

    def _recover(self, lane: _Lane, subset, exc, *,
                 retried: bool = False) -> int:
        """A subset execution failed: retry, bisect, quarantine.

        Transient faults get one same-set retry (backoff + budget),
        then the subset bisects — successful halves complete from the
        probe, the failing singleton is quarantined as poison (or, if
        its failures were transient, failed with a structured
        retries-exhausted error).  A form that trips the degradation
        threshold rebuilds the whole lane on the surviving form.
        """
        kind = classify(exc)
        if kind == FATAL:
            return self._fail_slots(lane, subset, exc)
        if kind == TRANSIENT and \
                self.executor.note_failure(lane.bucket, lane.d, lane.form):
            self._rebuild_lane(lane)
            return 0
        if len(subset) == 1:
            return self._recover_single(lane, subset, exc, kind)
        if kind == TRANSIENT and not retried and self._budget.spend():
            obs.counter("resilience_retries_total",
                        site="continuous.execute", kind=kind).inc()
            time.sleep(self.cfg.retry.backoff_s(2, self._rng))
            y, exc2 = self._try_execute(lane, subset)
            if exc2 is None:
                return self._complete_slots(lane, y, subset)
            exc, kind = exc2, classify(exc2)
            if kind == FATAL:
                return self._fail_slots(lane, subset, exc)
        # bisect: innocents complete from their half's probe, the
        # culprit's half recurses down to a singleton
        mid = len(subset) // 2
        done = 0
        for half in (subset[:mid], subset[mid:]):
            y, exc_h = self._try_execute(lane, half)
            if exc_h is None:
                done += self._complete_slots(lane, y, half)
            else:
                done += self._recover(lane, half, exc_h, retried=True)
        return done

    def _recover_single(self, lane: _Lane, subset, exc, kind: str) -> int:
        (_, s) = subset[0]
        if kind == POISON:
            with self._lock:
                i = subset[0][0]
                if lane.slots[i] is s:
                    lane.slots[i] = None
            return self._quarantine(s, exc, kind="poison")
        s.attempts += 1
        if self.cfg.retry.allows(s.attempts + 1) and self._budget.spend():
            obs.counter("resilience_retries_total",
                        site="continuous.execute", kind=kind).inc()
            time.sleep(self.cfg.retry.backoff_s(s.attempts + 1, self._rng))
            y, exc2 = self._try_execute(lane, subset)
            if exc2 is None:
                return self._complete_slots(lane, y, subset)
            return self._recover(lane, subset, exc2, retried=True)
        return self._fail_slots(lane, subset, TransientExecutorError(
            f"retries exhausted after {s.attempts} attempts "
            f"(last error: {exc!r})"))

    def _quarantine(self, s: _SlotReq, exc, *, kind: str) -> int:
        """Fail one request as the pinned culprit (slot already freed).
        The original exception is preserved — chaos poison already
        raises PoisonRequestError, and a caller's ValueError stays a
        ValueError."""
        self.quarantined += 1
        obs.counter("resilience_quarantined_total", kind=kind).inc()
        self._finish_error(s, exc)
        return 1

    def _fail_slots(self, lane: _Lane, subset, exc) -> int:
        with self._lock:
            for i, s in subset:
                if lane.slots[i] is s:
                    lane.slots[i] = None
        for _, s in subset:
            self._finish_error(s, exc)
        return len(subset)

    def _finish_error(self, s: _SlotReq, exc) -> None:
        with self._lock:
            self.completed += 1
            self.failed += 1
        if not s.future.done() and not s.future.cancelled():
            s.future.set_exception(exc)

    def _rebuild_lane(self, lane: _Lane) -> None:
        """The lane's form was degraded: re-admit every occupant and
        queued request through a fresh lane on the surviving form.
        Partially-run multi-step requests restart from their source
        features (deterministic executors make the redo exact)."""
        key = (lane.bucket, lane.d)
        with self._lock:
            reqs = [s for s in lane.slots if s is not None] \
                + list(lane.queue)
            lane.slots = [None] * len(lane.slots)
            lane.queue.clear()
            if self._lanes.get(key) is lane:
                del self._lanes[key]
        obs.counter("resilience_recoveries_total",
                    site="lane_rebuild").inc()
        for s in reqs:
            try:
                with self._lock:
                    nlane = self._lane_for(s.source,
                                           int(s.source_h.shape[1]),
                                           s.source_h.dtype)
                    src = s.source if s.source.has_form(nlane.form) \
                        else s.source.to(nlane.form)
                    s.matrix = pad_to_bucket(src, nlane.bucket,
                                             form=nlane.form)
                    s.features = paths.pad_rows(
                        s.source_h.astype(nlane.dtype), nlane.bucket.cols)
                    s.remaining = s.steps_total
                    if not nlane.admit(s):
                        self._shed_for(nlane, s)
            except Exception as exc:  # noqa: BLE001 — resolve, don't strand
                self._finish_error(s, exc)

    def _step_loop(self) -> None:
        while not self._stop.is_set():
            try:
                chaos.hook("continuous.worker")
            except chaos.WorkerKilled:
                return  # injected death: the supervisor restarts us
            if self.step() == 0:
                # nothing ready (idle, or occupants still inside their
                # batching window) — back off briefly
                time.sleep(self.cfg.idle_sleep_s)

    # -- lifecycle ----------------------------------------------------------

    def pending(self) -> int:
        with self._lock:
            return self.submitted - self.completed

    def drain(self, timeout: float = 60.0) -> None:
        """Step (or wait on the background thread) until every admitted
        request has resolved.  A dead background worker is restarted
        (bounded); past the restart budget the drain degrades to
        stepping inline, so the backlog still completes."""
        t0 = time.perf_counter()
        while self.pending() > 0:
            if time.perf_counter() - t0 > timeout:
                raise TimeoutError(
                    f"drain: {self.pending()} requests still pending "
                    f"after {timeout}s")
            if self._sup is None or not self._sup.ensure():
                self.step(force=True)
            else:
                time.sleep(0.002)

    def _fail_leftovers(self) -> None:
        """Sweep every occupied slot and queued request into
        EngineClosedError (close path, and the submit-vs-close race)."""
        with self._lock:
            leftovers = []
            for lane in self._lanes.values():
                leftovers += ([s for s in lane.slots if s is not None]
                              + list(lane.queue))
                lane.slots = [None] * len(lane.slots)
                lane.queue.clear()
        for s in leftovers:
            self._finish_error(s, EngineClosedError("engine closed"))

    def close(self) -> None:
        """Drain in-flight work, then stop.  Every future submitted
        before close resolves — with its result when the drain
        succeeds, with an error otherwise; none is left hanging.
        Idempotent, and safe to call concurrently from several threads
        (one closer does the work, the rest wait on its lock)."""
        with self._close_once:
            if self._closed:
                return
            try:
                self.drain()
            except Exception:  # noqa: BLE001 — fail the leftovers below
                pass
            self._stop.set()
            if self._sup is not None:
                self._sup.join(timeout=5.0)
            self._fail_leftovers()
            self._closed = True

    def __enter__(self) -> "ContinuousBatchEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def reset_metrics(self) -> None:
        """Zero traffic counters (keep compiled executors and lanes)."""
        if self.pending():
            raise RuntimeError("reset_metrics with requests in flight; "
                               "drain() first")
        with self._lock:
            self._latencies_ms.clear()
            self.submitted = self.completed = self.failed = 0
            self.quarantined = self.shed = 0
            for lane in self._lanes.values():
                lane.steps = lane.slot_steps = lane.occupied_steps = 0
            self.executor.waste = type(self.executor.waste)()
            self.executor.calls = self.executor.requests = 0

    # -- reporting ----------------------------------------------------------

    def report(self) -> Dict[str, Any]:
        """Canonical keys (see DESIGN.md "Observability"); the old
        ``latency_ms_p50``/``latency_ms_p99`` spellings resolve via
        deprecation aliases."""
        with self._lock:
            lat = np.asarray(self._latencies_ms, np.float64)
            lanes = {}
            for (bucket, d), lane in self._lanes.items():
                lanes[f"{bucket.label}/d{d}"] = {
                    "form": lane.form,
                    "slots": len(lane.slots),
                    "steps": lane.steps,
                    "occupancy": (lane.occupied_steps
                                  / max(lane.slot_steps, 1)),
                    "queued": len(lane.queue),
                }
            return obs.renamed_keys({
                "submitted": self.submitted,
                "completed": self.completed,
                "failed": self.failed,
                "pending": self.submitted - self.completed,
                "p50_ms": (float(np.percentile(lat, 50))
                           if len(lat) else 0.0),
                "p99_ms": (float(np.percentile(lat, 99))
                           if len(lat) else 0.0),
                "lanes": lanes,
                "executor": self.executor.report(),
                "resilience": {
                    "quarantined": self.quarantined,
                    "shed": self.shed,
                    "retry_tokens": self._budget.remaining(),
                    "worker_restarts": (self._sup.restarts
                                        if self._sup is not None else 0),
                },
            }, {"latency_ms_p50": "p50_ms", "latency_ms_p99": "p99_ms"})
