"""Adaptive bucket ladder: a compile grid learned from live traffic.

The fixed geometric grid (``repro.batch.bucketing``) quantizes every
request up by a constant growth factor, so on real traffic 40–55 % of
the streamed volume is padding (``BENCH_serve.json``).  The ladder
replaces the geometric rungs with **quantiles of the observed request
shapes**: each dimension (rows, nnz, ELL width) keeps ``n_rungs`` rung
values fit to the marginal distribution of a sliding window of traffic,
so the grid is dense exactly where requests actually land and the
expected pad-up per request shrinks from ~(growth+1)/2 to the
inter-quantile gap.

Three serving-specific mechanisms keep the learned grid cheap to run:

* **Drift detection** — the window's log₂ histograms are compared to the
  histograms frozen at fit time with a symmetric KL divergence; the
  ladder re-fits only when the mix has genuinely moved
  (``drift() > drift_threshold``).
* **Hysteresis** — drift is only *checked* every ``refit_interval``
  observations and never before ``min_fit`` observations exist, so a
  brief burst cannot thrash the grid.
* **Warm-executor carryover** — at re-fit, any new rung within
  ``snap_tol`` (relative) of an old rung *snaps to the old value*.
  Buckets are the jit-cache key of every ``BucketedExecutor`` program,
  so a snapped rung means the re-laddered grid keeps hitting the warm
  compiled executors instead of churning the cache; only rungs that
  actually moved pay a compile.

Requests that overflow the learned grid (larger than the top rung) fall
back to geometric quantization *from* the top rung, so the total number
of distinct buckets stays O(#rungs + log overflow).
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Deque, Dict, List, Optional

import numpy as np

from repro import obs
from repro.batch.bucketing import (Bucket, BucketingConfig,
                                   DEFAULT_BUCKETING, _round_to,
                                   quantize_up)
from repro.dispatch.stats import MatrixStats


@dataclasses.dataclass(frozen=True)
class LadderConfig:
    """Knobs of the online quantile fit."""

    n_rungs: int = 8           # rungs per dimension (rows / nnz / width)
    window: int = 512          # sliding observation window
    min_fit: int = 32          # observations before the first fit
    refit_interval: int = 64   # observations between drift checks
    drift_threshold: float = 0.25  # symmetric-KL trigger for a re-fit
    snap_tol: float = 0.25     # relative tol for warm-rung carryover
    fallback: BucketingConfig = DEFAULT_BUCKETING  # pre-fit / overflow


DEFAULT_LADDER = LadderConfig()

_DIMS = ("rows", "nnz", "width")


def _symmetric_kl(p: np.ndarray, q: np.ndarray, eps: float = 1e-9) -> float:
    """Symmetric KL between two (unnormalized) histograms."""
    p = p.astype(np.float64) + eps
    q = q.astype(np.float64) + eps
    p /= p.sum()
    q /= q.sum()
    return float(((p - q) * np.log(p / q)).sum())


def _log_hist(values: np.ndarray, n_bins: int = 24) -> np.ndarray:
    """Histogram of log2(values) over fixed bins [0, 24) (16M ceiling)."""
    lg = np.log2(np.maximum(values.astype(np.float64), 1.0))
    return np.histogram(lg, bins=n_bins, range=(0.0, float(n_bins)))[0]


def _fit_rungs(values: np.ndarray, n_rungs: int) -> np.ndarray:
    """Quantile rung values (ascending, unique, top = observed max)."""
    qs = np.linspace(1.0 / n_rungs, 1.0, n_rungs)
    rungs = np.quantile(values, qs, method="higher")
    return np.unique(rungs.astype(np.int64))


def _snap(new: np.ndarray, old: Optional[np.ndarray], tol: float
          ) -> tuple[np.ndarray, int]:
    """Snap new rungs onto old ones within relative ``tol``.

    Correctness never depends on rung values — selection is "smallest
    rung >= x, else geometric overflow" — so snapping a rung slightly
    up or down only trades a little padding for a warm executor.
    """
    if old is None or not len(old):
        return new, 0
    snapped = []
    carried = 0
    for r in new:
        j = int(np.argmin(np.abs(old - r)))
        if abs(int(old[j]) - int(r)) <= tol * max(int(r), 1):
            snapped.append(int(old[j]))
            carried += 1
        else:
            snapped.append(int(r))
    return np.unique(np.asarray(snapped, np.int64)), carried


class AdaptiveBucketLadder:
    """Online quantile-learned bucket grid over (rows, nnz, width).

    Thread-safe: ``observe``/``bucket_for`` may be called from a serving
    worker while ``report`` reads from another thread.
    """

    def __init__(self, config: LadderConfig = DEFAULT_LADDER):
        self.config = config
        self._obs: Dict[str, Deque[int]] = {
            d: collections.deque(maxlen=config.window) for d in _DIMS}
        self._rungs: Dict[str, Optional[np.ndarray]] = {
            d: None for d in _DIMS}
        self._fit_hist: Dict[str, np.ndarray] = {}
        self._since_check = 0
        self._lock = threading.RLock()
        # counters
        self.observed = 0
        self.refits = 0
        self.drift_checks = 0
        self.fallbacks = 0     # requests bucketed off the geometric grid
        self.snapped_rungs = 0  # rungs carried warm across re-fits
        self.last_drift = 0.0

    # -- observation / fitting ---------------------------------------------

    def observe(self, stats: MatrixStats) -> None:
        """Record one request's shape marginals; re-fit on drift."""
        with self._lock:
            self._obs["rows"].append(int(stats.shape[0]))
            self._obs["nnz"].append(max(int(stats.nnz), 1))
            self._obs["width"].append(max(int(stats.ell_width), 1))
            self.observed += 1
            obs.counter("ladder_observed_total").inc()
            self._since_check += 1
            self._maybe_refit()

    @property
    def fitted(self) -> bool:
        return self._rungs["rows"] is not None

    def drift(self) -> float:
        """Symmetric KL between the window's and the fit-time log₂
        histograms, maxed over the (rows, nnz) marginals."""
        with self._lock:
            if not self.fitted or not self._fit_hist:
                return 0.0
            return max(
                _symmetric_kl(_log_hist(np.asarray(self._obs[d])),
                              self._fit_hist[d])
                for d in ("rows", "nnz"))

    def _maybe_refit(self) -> bool:
        n = len(self._obs["rows"])
        if not self.fitted:
            if n < self.config.min_fit:
                return False
            self._fit()
            return True
        if self._since_check < self.config.refit_interval:
            return False
        self._since_check = 0
        self.drift_checks += 1
        obs.counter("ladder_drift_checks_total").inc()
        self.last_drift = self.drift()
        obs.gauge("ladder_last_drift").set(self.last_drift)
        if self.last_drift <= self.config.drift_threshold:
            return False  # hysteresis: mix hasn't moved, keep the grid
        self._fit()
        return True

    def _fit(self) -> None:
        for d in _DIMS:
            vals = np.asarray(self._obs[d], np.int64)
            new = _fit_rungs(vals, self.config.n_rungs)
            new, carried = _snap(new, self._rungs[d],
                                 self.config.snap_tol)
            self._rungs[d] = new
            self.snapped_rungs += carried
            self._fit_hist[d] = _log_hist(vals)
        self.refits += 1
        obs.counter("ladder_refits_total").inc()
        self._since_check = 0

    def refit(self) -> None:
        """Force an immediate fit from the current window."""
        with self._lock:
            if len(self._obs["rows"]):
                self._fit()

    # -- bucketing ----------------------------------------------------------

    def _pick(self, dim: str, x: int) -> int:
        """Smallest learned rung >= x; geometric overflow past the top."""
        rungs = self._rungs[dim]
        i = int(np.searchsorted(rungs, x, side="left"))
        if i < len(rungs):
            return int(rungs[i])
        # overflow: geometric growth anchored at the top rung keeps the
        # key space O(log overflow) instead of one bucket per shape
        return quantize_up(x, int(rungs[-1]),
                           self.config.fallback.growth)

    def bucket_for(self, stats: MatrixStats) -> Bucket:
        """The learned-grid bucket for these request stats (geometric
        fallback until ``min_fit`` observations have been seen)."""
        from repro.batch.bucketing import bucket_for as fixed_bucket_for

        with self._lock:
            if not self.fitted:
                self.fallbacks += 1
                obs.counter("ladder_fallbacks_total").inc()
                return fixed_bucket_for(stats, self.config.fallback)
            bm, bn = stats.block_m, stats.block_n
            rows = _round_to(self._pick("rows", stats.shape[0]), bm)
            cols = _round_to(self._pick("rows", stats.shape[1]), bn)
            nnz = self._pick("nnz", max(stats.nnz, 1))
            width = self._pick("width", max(stats.ell_width, 1))
            return Bucket(rows=rows, cols=cols, nnz=nnz, width=width,
                          block_m=bm, block_n=bn)

    # -- reporting ----------------------------------------------------------

    def rungs(self) -> Dict[str, List[int]]:
        with self._lock:
            return {d: ([] if self._rungs[d] is None
                        else [int(x) for x in self._rungs[d]])
                    for d in _DIMS}

    def report(self) -> Dict[str, object]:
        with self._lock:
            return {
                "fitted": self.fitted,
                "observed": self.observed,
                "refits": self.refits,
                "drift_checks": self.drift_checks,
                "last_drift": round(self.last_drift, 4),
                "fallbacks": self.fallbacks,
                "snapped_rungs": self.snapped_rungs,
                "rungs": self.rungs(),
            }
